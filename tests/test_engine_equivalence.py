"""Equivalence: the batched PrefillRunner / compacted DecodeRunner must
reproduce the seed engine's single-row path bit-for-bit.

The seed path is still constructible (``batched_prefill=False`` +
``compact_decode=False`` forces single-row prefill groups and full
``max_slots`` decode), so every test runs the same workload through both
configurations and compares tokens and behavior logprobs exactly — greedy
decoding makes token selection key-independent, and on the XLA CPU/TPU
backends batched matmul rows are bitwise independent, so equality is exact,
not approximate."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.types import Trajectory, reset_traj_ids
from repro.models import model as M
from repro.rollout.engine import RolloutInstance
from repro.rollout import runners

CFG = get_arch("qwen2-1.5b").reduced()
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))


def mk_traj(tid, prompt_len=6, max_new=16):
    prompt = list(np.random.RandomState(tid).randint(3, 17, size=prompt_len))
    return Trajectory(traj_id=tid, prompt=prompt, max_new_tokens=max_new)


def mk_inst(*, legacy: bool, slots=4, max_len=64, seed=0, **kw):
    return RolloutInstance(
        0, CFG, PARAMS, 0, max_slots=slots, max_len=max_len,
        temperature=0.0, seed=seed,
        batched_prefill=not legacy, compact_decode=not legacy, **kw,
    )


def run_workload(inst, trajs, steps=60):
    """Route everything up front, then decode until all complete."""
    for t in trajs:
        inst.route(t)
    done = []
    for _ in range(steps):
        done.extend(inst.step())
        if len(done) == len(trajs):
            break
    return done


def assert_same_streams(trajs_a, trajs_b):
    for ta, tb in zip(trajs_a, trajs_b):
        assert ta.traj_id == tb.traj_id
        assert ta.response == tb.response, (
            f"traj {ta.traj_id}: batched {ta.response} != seed {tb.response}"
        )
        a = np.asarray(ta.behavior_logprobs)
        b = np.asarray(tb.behavior_logprobs)
        np.testing.assert_array_equal(
            a, b, err_msg=f"traj {ta.traj_id} behavior logprobs diverge"
        )


@pytest.mark.parametrize("n_trajs,prompt_lens", [
    (3, (6, 6, 6)),            # one shared bucket -> one batched forward
    (4, (5, 21, 9, 17)),       # two buckets (16/32) -> grouped forwards
    (6, (6, 7, 8, 9, 10, 11)), # more trajs than slots -> waiting queue
])
def test_batched_prefill_and_compact_decode_match_seed(n_trajs, prompt_lens):
    reset_traj_ids()
    mk = lambda: [
        mk_traj(100 + i, prompt_len=pl, max_new=10)
        for i, pl in enumerate(prompt_lens)
    ]
    done_new = run_workload(mk_inst(legacy=False), mk())
    done_seed = run_workload(mk_inst(legacy=True), mk())
    assert len(done_new) == len(done_seed) == n_trajs
    key = lambda t: t.traj_id
    assert_same_streams(sorted(done_new, key=key), sorted(done_seed, key=key))


def test_single_active_slot_decode_matches_seed():
    """1 active of 4 slots: the compact path decodes a 1-row bucket while
    the seed path decodes all 4 rows — same tokens, same logprobs."""
    reset_traj_ids()
    t_new, t_seed = mk_traj(7, max_new=12), mk_traj(7, max_new=12)
    inst_new, inst_seed = mk_inst(legacy=False), mk_inst(legacy=True)
    run_workload(inst_new, [t_new])
    run_workload(inst_seed, [t_seed])
    assert t_new.finished and t_seed.finished
    assert_same_streams([t_new], [t_seed])
    # and the compact path really did decode fewer rows' worth of work
    assert inst_new.decode_tokens == inst_seed.decode_tokens


def test_interrupt_migrate_reprefill_matches_seed():
    """Partial rollout: interrupt mid-stream, migrate, re-prefill (the
    batched path re-prefills prompt+partial response like the seed)."""
    reset_traj_ids()

    def migrate(legacy):
        t = mk_traj(11, max_new=12)
        a = mk_inst(legacy=legacy)
        b = mk_inst(legacy=legacy)
        a.route(t)
        for _ in range(4):
            a.step()
        a.interrupt([t.traj_id])
        b.route(t)
        for _ in range(60):
            if t.finished:
                break
            b.step()
        return t

    assert_same_streams([migrate(False)], [migrate(True)])


def test_kv_budget_admission_decisions_match_seed():
    """Batched admission must make the same admit/defer decisions the seed
    slot-scan made under a tight KV budget."""
    reset_traj_ids()
    k5 = 2 * CFG.n_layers * CFG.n_kv_heads * CFG.hd * 4
    budget = k5 * 40  # room for ~2 short trajectories, not 4
    mk = lambda: [mk_traj(200 + i, prompt_len=8, max_new=6) for i in range(4)]

    def admit_sets(legacy):
        inst = mk_inst(legacy=legacy, kv_budget=budget)
        for t in mk():
            inst.route(t)
        s = inst.snapshot()
        return s.run_trajs, s.wait_trajs

    assert admit_sets(False) == admit_sets(True)


def test_cache_rows_bitwise_identical_after_batched_prefill():
    """The fused multi-row scatter writes exactly what the per-row
    tree_map scatter wrote."""
    reset_traj_ids()
    mk = lambda: [mk_traj(300 + i, prompt_len=6, max_new=8) for i in range(3)]
    inst_new, inst_seed = mk_inst(legacy=False), mk_inst(legacy=True)
    for t in mk():
        inst_new.route(t)
    for t in mk():
        inst_seed.route(t)
    for name in inst_new.cache:
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"cache[{name}]"
            ),
            inst_new.cache[name],
            inst_seed.cache[name],
        )
    np.testing.assert_array_equal(
        np.asarray(inst_new._last_tokens), np.asarray(inst_seed._last_tokens)
    )


def test_route_many_wave_matches_sequential_seed_routes():
    """A route_many wave (the executor's coalesced form) must produce the
    same streams as the seed's one-route()-at-a-time admission."""
    reset_traj_ids()
    mk = lambda: [mk_traj(500 + i, prompt_len=6 + i, max_new=8) for i in range(4)]

    inst_new = mk_inst(legacy=False)
    wave = mk()
    inst_new.route_many(wave)
    done_new = []
    for _ in range(60):
        done_new.extend(inst_new.step())
        if len(done_new) == 4:
            break

    done_seed = run_workload(mk_inst(legacy=True), mk())
    key = lambda t: t.traj_id
    assert_same_streams(sorted(done_new, key=key), sorted(done_seed, key=key))


def test_stochastic_prefill_sampling_matches_seed():
    """Prefill sampling keys are split per trajectory (seed order), so even
    stochastic (temperature=1) first tokens match the seed path bitwise —
    the vmapped per-row sampler must equal the per-row sample() loop."""
    reset_traj_ids()

    def first_tokens(legacy):
        inst = RolloutInstance(
            0, CFG, PARAMS, 0, max_slots=4, max_len=64, temperature=1.0,
            seed=3, batched_prefill=not legacy, compact_decode=not legacy,
        )
        trajs = [mk_traj(400 + i, prompt_len=5 + i) for i in range(4)]
        for t in trajs:
            inst.route(t)
        return [(t.response[0], t.behavior_logprobs[0]) for t in trajs]

    assert first_tokens(False) == first_tokens(True)


def test_gather_scatter_roundtrip_identity():
    cache = M.init_cache(CFG, 4, 32)
    cache = {k: jax.tree_util.tree_map(
        lambda a: a + np.float32(1.5) if a.dtype != np.int32 else a + 1, v)
        for k, v in cache.items()}
    rows = jax.numpy.asarray([1, 3])
    sub = runners.gather_rows(cache, rows)
    back = runners.scatter_rows(cache, sub, rows)
    for name in cache:
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)
            ),
            cache[name],
            back[name],
        )


def test_decode_bucket_sizes():
    r = runners.DecodeRunner(CFG, max_slots=8)
    assert r.bucket_of(1) == 1
    assert r.bucket_of(2) == 2
    assert r.bucket_of(3) == 4
    assert r.bucket_of(5) == 8
    assert r.bucket_of(8) == 8


# ===================================================== paged KV equivalence
# The block-paged cache (shared pool + per-trajectory block tables) must be
# bit-for-bit equivalent to the dense per-slot layout under greedy decoding:
# valid cache lanes hold identical values and masked lanes contribute exact
# zeros, so tokens AND behavior logprobs match — including across slot
# reuse, interrupt/migrate re-prefill, and KV-budget admission.

def mk_paged(*, slots=4, max_len=64, seed=0, block_size=16, **kw):
    return RolloutInstance(
        0, CFG, PARAMS, 0, max_slots=slots, max_len=max_len,
        temperature=0.0, seed=seed, paged=True, kv_block_size=block_size,
        **kw,
    )


@pytest.mark.parametrize("n_trajs,prompt_lens", [
    (3, (6, 6, 6)),            # one shared bucket
    (4, (5, 21, 9, 17)),       # two prefill buckets
    (6, (6, 7, 8, 9, 10, 11)), # slot reuse through the waiting queue
])
def test_paged_decode_matches_dense(n_trajs, prompt_lens):
    reset_traj_ids()
    mk = lambda: [
        mk_traj(600 + i, prompt_len=pl, max_new=10)
        for i, pl in enumerate(prompt_lens)
    ]
    done_paged = run_workload(mk_paged(), mk())
    done_dense = run_workload(mk_inst(legacy=False), mk())
    assert len(done_paged) == len(done_dense) == n_trajs
    key = lambda t: t.traj_id
    assert_same_streams(
        sorted(done_paged, key=key), sorted(done_dense, key=key)
    )


@pytest.mark.parametrize("block_size", [8, 16, 32, 64])
def test_paged_block_size_sweep_matches_dense(block_size):
    reset_traj_ids()
    mk = lambda: [mk_traj(700 + i, prompt_len=6 + i, max_new=8) for i in range(3)]
    done_paged = run_workload(mk_paged(block_size=block_size), mk())
    done_dense = run_workload(mk_inst(legacy=False), mk())
    key = lambda t: t.traj_id
    assert_same_streams(
        sorted(done_paged, key=key), sorted(done_dense, key=key)
    )


def test_paged_interrupt_migrate_reprefill_matches_dense():
    """Partial rollout across instances: blocks are freed at interrupt and
    reallocated at re-prefill on the destination replica."""
    reset_traj_ids()

    def migrate(paged):
        t = mk_traj(11, max_new=12)
        a = mk_paged() if paged else mk_inst(legacy=False)
        b = mk_paged() if paged else mk_inst(legacy=False)
        a.route(t)
        for _ in range(4):
            a.step()
        a.interrupt([t.traj_id])
        if paged:
            a.allocator.check()
            assert a.allocator.used_blocks == 0
        b.route(t)
        for _ in range(60):
            if t.finished:
                break
            b.step()
        return t

    assert_same_streams([migrate(True)], [migrate(False)])


def test_paged_preemption_on_block_exhaustion():
    """A pool too small for all residents preempts the youngest trajectory
    back to the waiting queue; greedy token streams still match dense and
    no block leaks."""
    reset_traj_ids()
    NO_EOS = -1

    def run(paged):
        if paged:
            # 9 blocks x 8 tokens = 72 token capacity for 3 trajectories
            # growing to ~35 tokens each -> exhaustion mid-decode
            inst = RolloutInstance(
                0, CFG, PARAMS, 0, max_slots=3, max_len=64,
                temperature=0.0, seed=0, eos_id=NO_EOS,
                paged=True, kv_block_size=8, kv_pool_blocks=9,
            )
        else:
            inst = RolloutInstance(
                0, CFG, PARAMS, 0, max_slots=3, max_len=64,
                temperature=0.0, seed=0, eos_id=NO_EOS,
            )
        trajs = [mk_traj(800 + i, prompt_len=5 + i, max_new=30) for i in range(3)]
        for t in trajs:
            inst.route(t)
        done = []
        for _ in range(400):
            done.extend(inst.step())
            if inst.allocator is not None:
                inst.allocator.check()
            if len(done) == 3:
                break
        return inst, sorted(done, key=lambda t: t.traj_id)

    inst_p, done_p = run(True)
    inst_d, done_d = run(False)
    assert inst_p.preemptions > 0
    assert len(done_p) == len(done_d) == 3
    for a, b in zip(done_p, done_d):
        assert a.traj_id == b.traj_id
        assert a.response == b.response
    assert inst_p.allocator.used_blocks == 0
    inst_p.allocator.check()


def test_paged_admits_within_block_budget():
    """Admission charges actual allocated blocks against the budget."""
    reset_traj_ids()
    k5 = 2 * CFG.n_layers * CFG.n_kv_heads * CFG.hd * 4
    bs = 16
    budget = k5 * bs * 3  # room for exactly 3 blocks
    inst = mk_paged(block_size=bs, kv_budget=budget, slots=4)
    for i in range(4):
        inst.route(mk_traj(900 + i, prompt_len=6, max_new=6))
    s = inst.snapshot()
    # each short trajectory occupies one block but is charged headroom
    # (6 + 16 tokens -> 2 blocks) at the admission decision
    assert len(s.run_trajs) == 2
    assert s.kv_cache == k5 * bs * 2
    assert inst.kv_bytes() == k5 * inst.allocator.used_tokens()


def test_dense_incremental_kv_counter_stays_exact():
    """The O(1) admission counter must track the O(slots) recomputation
    through admission, decode, completion, and interrupts."""
    reset_traj_ids()
    inst = mk_inst(legacy=False)
    trajs = [mk_traj(950 + i, prompt_len=6 + i, max_new=8) for i in range(6)]
    for t in trajs:
        inst.route(t)
        assert inst.kv_bytes() == inst._recompute_kv_bytes()
    for _ in range(20):
        inst.step()
        assert inst.kv_bytes() == inst._recompute_kv_bytes()
    resident = [t.traj_id for t in inst.slots if t is not None][:2]
    inst.interrupt(resident)
    assert inst.kv_bytes() == inst._recompute_kv_bytes()


# ==================================================== prefix-shared groups
# Group sampling (GRPO/DAPO): group members share one prompt. With
# share_prefix=True the paged engine prefills the prompt ONCE, maps its
# full blocks read-only into every member's table (refcounted), and
# CoW-copies the partial tail block per member. Greedy decode must be
# bit-for-bit equal to group_size independent prefills — including after
# CoW, preemption, and re-admission.

def mk_group(base, n, prompt_len=21, max_new=10, gid=0, seed=1234):
    prompt = list(np.random.RandomState(seed).randint(3, 17, size=prompt_len))
    return [
        Trajectory(traj_id=base + i, prompt=list(prompt), group_id=gid,
                   max_new_tokens=max_new)
        for i in range(n)
    ]


def mk_sharing(*, share: bool, slots=4, max_len=64, block_size=16, **kw):
    return RolloutInstance(
        0, CFG, PARAMS, 0, max_slots=slots, max_len=max_len,
        temperature=0.0, seed=0, paged=True, kv_block_size=block_size,
        share_prefix=share, **kw,
    )


@pytest.mark.parametrize("prompt_len", [21, 32, 7])   # CoW tail / aligned / sub-block
def test_group_shared_prefix_matches_independent(prompt_len):
    """group_size=4 off one shared prefix == 4 independent prefills,
    exactly (tokens + behavior logprobs), while allocating the prompt's
    full blocks once and prefilling the prompt once."""
    reset_traj_ids()
    bs = 16
    done_s = run_workload(
        mk_sharing(share=True, block_size=bs),
        mk_group(1000, 4, prompt_len=prompt_len),
    )
    done_i = run_workload(
        mk_sharing(share=False, block_size=bs),
        mk_group(1000, 4, prompt_len=prompt_len),
    )
    assert len(done_s) == len(done_i) == 4
    key = lambda t: t.traj_id
    assert_same_streams(sorted(done_s, key=key), sorted(done_i, key=key))


def test_group_admission_allocates_prefix_blocks_once():
    """Acceptance: a group of G members over a P-token prompt allocates
    blocks_for(P) blocks for the whole group exactly once — full blocks
    refcounted G ways and (lazy CoW, the default) ONE shared tail block
    that members only copy at first divergence — and prefills P tokens
    once. The first decode step diverges every member: the tail is then
    copied per member (minus the last owner, who writes in place)."""
    reset_traj_ids()
    bs, P, G = 16, 37, 4                  # 2 full blocks + 5-token tail
    inst = mk_sharing(share=True, slots=G, block_size=bs)
    inst.route_many(mk_group(1100, G, prompt_len=P))
    n_full, tail = divmod(P, bs)
    assert inst.n_active() == G
    assert inst.allocator.used_blocks == n_full + (1 if tail else 0)
    assert inst.allocator.shared_blocks == n_full + (1 if tail else 0)
    assert inst.prefill_tokens == P       # one pass over the prompt
    assert inst.shared_prefix_hits == G - 1
    assert inst.prefill_tokens_saved == (G - 1) * P
    assert inst.kv_bytes() == inst.k5 * bs * (n_full + 1)
    assert inst.block_copies == 0         # nobody diverged yet
    inst.allocator.check()
    inst.step()                           # first decode write: divergence
    assert inst.block_copies == G - 1     # last owner wrote in place
    assert inst.allocator.used_blocks == n_full + G
    assert inst.kv_bytes() == inst.k5 * bs * (n_full + G)
    inst.allocator.check()


def test_group_admission_eager_cow_allocates_tails_up_front():
    """lazy_cow=False restores the eager PR-3 behavior: the partial tail
    is copied into a private block per member at admission."""
    reset_traj_ids()
    bs, P, G = 16, 37, 4
    inst = mk_sharing(share=True, slots=G, block_size=bs, lazy_cow=False)
    inst.route_many(mk_group(1100, G, prompt_len=P))
    n_full = P // bs
    assert inst.allocator.used_blocks == n_full + G
    assert inst.allocator.shared_blocks == n_full
    assert inst.block_copies == G - 1     # eager tail copies at admission
    assert inst.kv_bytes() == inst.k5 * bs * (n_full + G)
    inst.step()
    assert inst.block_copies == G - 1     # no further copies at decode
    inst.allocator.check()


def test_group_stochastic_decode_diverges_and_matches_independent():
    """temperature=1: members sample different responses (CoW tails and
    private response blocks really diverge), and the shared path still
    matches the independent path bitwise — same slot layout, same key
    sequence, identical logits rows."""
    reset_traj_ids()

    def run(share):
        inst = RolloutInstance(
            0, CFG, PARAMS, 0, max_slots=4, max_len=64, temperature=1.0,
            seed=11, paged=True, kv_block_size=16, share_prefix=share,
        )
        return run_workload(inst, mk_group(1200, 4, prompt_len=21, max_new=8))

    done_s, done_i = run(True), run(False)
    key = lambda t: t.traj_id
    assert_same_streams(sorted(done_s, key=key), sorted(done_i, key=key))
    # divergence: not every member produced the same response
    responses = {tuple(t.response) for t in done_s}
    assert len(responses) > 1, "stochastic members never diverged"


def test_group_preemption_and_readmission_matches_unconstrained():
    """A pool too small for the whole group preempts members mid-decode;
    preempted members re-admit via exclusive re-prefill. Greedy streams
    must match a run with an ample pool, and no block may leak."""
    reset_traj_ids()
    NO_EOS = -1

    def run(pool_blocks):
        inst = RolloutInstance(
            0, CFG, PARAMS, 0, max_slots=3, max_len=64,
            temperature=0.0, seed=0, eos_id=NO_EOS,
            paged=True, kv_block_size=8, kv_pool_blocks=pool_blocks,
            share_prefix=True,
        )
        trajs = mk_group(1300, 3, prompt_len=13, max_new=30)
        inst.route_many(trajs)
        done = []
        for _ in range(400):
            done.extend(inst.step())
            inst.allocator.check()
            if len(done) == 3:
                break
        return inst, sorted(done, key=lambda t: t.traj_id)

    inst_small, done_small = run(10)   # 80-token pool for ~43*3 tokens
    inst_big, done_big = run(64)
    assert inst_small.preemptions > 0, "pool never exhausted"
    assert inst_big.preemptions == 0
    assert len(done_small) == len(done_big) == 3
    for a, b in zip(done_small, done_big):
        assert a.traj_id == b.traj_id
        assert a.response == b.response
    assert inst_small.allocator.used_blocks == 0
    inst_small.allocator.check()


@pytest.mark.parametrize("lazy", [True, False])
def test_group_interrupt_releases_shared_blocks_once(lazy):
    """Interrupting members one by one frees only their exclusive blocks;
    the shared prompt blocks return to the pool with the last member.
    Under lazy CoW undiverged members own NO exclusive blocks — the whole
    group footprint (full blocks + one shared tail) releases with the
    last member."""
    reset_traj_ids()
    bs, P, G = 16, 37, 3
    inst = mk_sharing(share=True, slots=G, block_size=bs, lazy_cow=lazy)
    group = mk_group(1400, G, prompt_len=P)
    inst.route_many(group)
    used = inst.allocator.used_blocks
    n_full = P // bs
    assert used == n_full + (1 if lazy else G)
    inst.interrupt([group[0].traj_id])
    # lazy: member 0 never diverged, so it frees nothing (refs drop only);
    # eager: its private tail copy returns to the pool
    assert inst.allocator.used_blocks == used - (0 if lazy else 1)
    inst.interrupt([group[1].traj_id])
    assert inst.allocator.used_blocks == used - (0 if lazy else 2)
    inst.interrupt([group[2].traj_id])
    assert inst.allocator.used_blocks == 0                 # prefix released
    assert inst.snapshot().prefix_groups == {}
    assert inst.snapshot().prefix_tail_members == {}
    inst.allocator.check()


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_group_straggler_forks_resident_prefix_across_waves(temperature):
    """A member admitted AFTER its siblings (no free slot in their wave)
    forks the still-resident prefix: no duplicate prompt blocks, and the
    token stream still matches the all-independent path bit-for-bit —
    greedy AND stochastic (suffix prefill keeps logits bitwise equal, and
    sampling keys are pure functions of (seed, traj_id, position))."""
    reset_traj_ids()
    bs, P = 16, 37                       # 2 full shared blocks + tail
    NO_EOS = -1

    def run(share):
        inst = RolloutInstance(
            0, CFG, PARAMS, 0, max_slots=2, max_len=64,
            temperature=temperature, seed=0, eos_id=NO_EOS,
            paged=True, kv_block_size=bs, share_prefix=share,
        )
        group = mk_group(1600, 3, prompt_len=P, max_new=6)
        # stagger budgets: member 0 finishes first, freeing a slot while
        # member 1 still holds the shared prefix for the straggler to fork
        group[0].max_new_tokens = 3
        inst.route_many(group)           # only 2 slots: member 3 waits
        assert inst.n_active() == 2
        if share:
            # two members share fully (two full blocks + the one lazy
            # tail); the third joins when a slot frees
            assert inst.allocator.used_blocks == 2 + 1
        done = []
        for _ in range(100):
            done.extend(inst.step())
            inst.allocator.check()
            if len(done) == 3:
                break
        return inst, sorted(done, key=lambda t: t.traj_id)

    inst_s, done_s = run(True)
    inst_i, done_i = run(False)
    assert inst_s.shared_prefix_hits == 2   # one in-wave, one cross-wave fork
    assert_same_streams(done_s, done_i)
    assert inst_s.allocator.used_blocks == 0
    inst_s.allocator.check()


def test_straggler_fork_survives_donor_interrupt_mid_decode():
    """Regression: a straggler forks the resident prefix, then its DONOR is
    interrupted mid-decode. The forked blocks are refcounted, so the
    donor's release must not free them out from under the straggler — the
    allocator invariants hold at every step and the straggler's stream
    still matches the all-independent path bit-for-bit."""
    reset_traj_ids()
    bs, P = 16, 37
    NO_EOS = -1

    def run(share):
        inst = RolloutInstance(
            0, CFG, PARAMS, 0, max_slots=2, max_len=64,
            temperature=0.0, seed=0, eos_id=NO_EOS,
            paged=True, kv_block_size=bs, share_prefix=share,
        )
        group = mk_group(1700, 3, prompt_len=P, max_new=8)
        group[0].max_new_tokens = 2      # frees a slot while member 1 decodes
        inst.route_many(group)
        done = []
        for _ in range(100):
            done.extend(inst.step())
            inst.allocator.check()
            if any(t.traj_id == 1700 for t in done):
                break
        # the straggler was admitted in the wave that freed the slot; the
        # donor (member 1) is mid-decode — kick the donor now
        tbl = list(inst.allocator.table(1702))
        kicked = inst.interrupt([1701])
        assert [t.traj_id for t in kicked] == [1701]
        inst.allocator.check()
        if share:
            # the straggler is now the sole owner of the forked prompt
            # blocks: the donor's release decremented, not freed, them
            for blk in tbl[:2]:
                assert inst.allocator.refcount(blk) == 1
        for _ in range(100):
            done.extend(inst.step())
            inst.allocator.check()
            if any(t.traj_id == 1702 for t in done):
                break
        return inst, [t for t in done if t.traj_id == 1702]

    inst_s, done_s = run(True)
    inst_i, done_i = run(False)
    assert inst_s.shared_prefix_hits == 2
    assert len(done_s) == 1
    assert_same_streams(done_s, done_i)
    assert inst_s.allocator.used_blocks == 0
    inst_s.allocator.check()


def test_lazy_cow_skips_copies_for_members_that_never_decode():
    """Copy traffic is strictly lower under lazy CoW: members interrupted
    between admission and their first decode step never diverge, so their
    tail copies never happen — eager CoW has already paid them at
    admission. The surviving member's stream is unchanged (the last
    undiverged owner appends in place)."""
    reset_traj_ids()

    def run(lazy):
        inst = mk_sharing(share=True, slots=4, lazy_cow=lazy)
        group = mk_group(1800, 3, prompt_len=21, max_new=4)
        inst.route_many(group)
        admission_copies = inst.block_copies
        # coordinator kicks two members before the first decode dispatch
        inst.interrupt([1801, 1802])
        inst.allocator.check()
        done = []
        for _ in range(20):
            done.extend(inst.step())
            inst.allocator.check()
            if done:
                break
        return inst, admission_copies, done

    inst_l, adm_l, done_l = run(True)
    inst_e, adm_e, done_e = run(False)
    assert adm_e == 2                    # eager: G-1 tail copies up front
    assert adm_l == 0
    assert inst_e.block_copies == 2
    assert inst_l.block_copies == 0      # survivor was the last owner
    assert inst_l.block_copies < inst_e.block_copies
    assert_same_streams(done_l, done_e)
    assert inst_l.allocator.used_blocks == 0
    inst_l.allocator.check()


def test_group_partial_members_do_not_share():
    """A member with a partial response (diverged KV) must re-prefill
    exclusively even when routed alongside its fresh siblings."""
    reset_traj_ids()
    inst = mk_sharing(share=True, slots=4)
    group = mk_group(1500, 3, prompt_len=21, max_new=12)
    partial = group[0]
    partial.response = [5, 6]
    partial.behavior_logprobs = [-1.0, -1.0]
    inst.route_many(group)
    # siblings 1,2 share; the partial member prefills alone
    assert inst.shared_prefix_hits == 1
    assert inst.prefill_tokens == 23 + 21
    inst.allocator.check()


def test_paged_admission_wave_uses_live_free_count():
    """Blocks drawn by earlier admissions in the same wave must not be
    double-counted against the pool: with 9 free blocks, a 5-block and a
    3-block trajectory admit together."""
    reset_traj_ids()
    inst = RolloutInstance(
        0, CFG, PARAMS, 0, max_slots=4, max_len=64, temperature=0.0,
        paged=True, kv_block_size=8, kv_pool_blocks=9,
    )
    a = mk_traj(970, prompt_len=33, max_new=4)   # ceil(33/8) = 5 blocks
    b = mk_traj(971, prompt_len=17, max_new=4)   # ceil(17/8) = 3 blocks
    inst.route_many([a, b])
    s = inst.snapshot()
    assert s.run_trajs == {970, 971}
    assert inst.allocator.used_blocks == 8
    inst.allocator.check()


# ============================================== per-slot PRNG key streams
# The sampling key for trajectory t's p-th token is
# fold_in(fold_in(base, t), p) — a pure function of (seed, traj_id,
# position). Stochastic decode is therefore bit-for-bit invariant under
# batch composition, slot assignment, instance identity, and migration
# destination: the properties the ROADMAP's "batched sampling key
# redesign" item called for.


def test_stochastic_stream_invariant_under_batch_composition():
    """The same trajectory sampled alone vs sharing the batch with three
    neighbours (different compaction bucket, different slot) produces the
    identical stochastic token stream."""
    reset_traj_ids()

    def run(neighbours):
        inst = RolloutInstance(
            0, CFG, PARAMS, 0, max_slots=4, max_len=64, temperature=1.0,
            seed=5,
        )
        target = mk_traj(700, prompt_len=9, max_new=10)
        others = [
            mk_traj(710 + i, prompt_len=6 + i, max_new=10)
            for i in range(neighbours)
        ]
        # neighbours admitted FIRST: the target lands in a different slot
        # and a different compaction bucket than when alone
        run_workload(inst, others + [target])
        return target

    alone = run(0)
    crowded = run(3)
    assert alone.response == crowded.response
    np.testing.assert_array_equal(
        np.asarray(alone.behavior_logprobs),
        np.asarray(crowded.behavior_logprobs),
    )


def test_stochastic_stream_invariant_under_instance_identity():
    """Different inst_id, same seed: identical streams — a migrated
    trajectory would sample the same tokens on any replica."""
    reset_traj_ids()

    def run(inst_id):
        inst = RolloutInstance(
            inst_id, CFG, PARAMS, 0, max_slots=2, max_len=64,
            temperature=1.0, seed=9,
        )
        t = mk_traj(800, prompt_len=7, max_new=8)
        run_workload(inst, [t])
        return t

    assert_same_streams([run(0)], [run(5)])


def test_stochastic_migration_destination_invariant():
    """Interrupt mid-stream, then finish on instance B vs instance C (with
    different occupancy): the continuation resumes the key stream at its
    position and the final streams match bitwise."""
    reset_traj_ids()

    def run(busy_dest):
        src = RolloutInstance(
            0, CFG, PARAMS, 0, max_slots=4, max_len=64, temperature=1.0,
            seed=13,
        )
        t = mk_traj(900, prompt_len=8, max_new=12)
        src.route(t)
        for _ in range(4):
            src.step()
        src.interrupt([t.traj_id])
        dest = RolloutInstance(
            1 + int(busy_dest), CFG, PARAMS, 0, max_slots=4, max_len=64,
            temperature=1.0, seed=13,
        )
        if busy_dest:
            # different batch composition at the destination
            dest.route_many(
                [mk_traj(910 + i, prompt_len=5 + i, max_new=20)
                 for i in range(2)]
            )
        dest.route(t)
        for _ in range(80):
            if t.finished:
                break
            dest.step()
        assert t.finished
        return t

    assert_same_streams([run(False)], [run(True)])


def test_stream_keys_match_scalar_stream_key():
    from repro.rollout import sampler

    base = jax.random.PRNGKey(3)
    ids = jax.numpy.asarray([4, 99, 4], jax.numpy.uint32)
    pos = jax.numpy.asarray([0, 7, 1], jax.numpy.uint32)
    batched = np.asarray(sampler.stream_keys(base, ids, pos))
    for row, (i, p) in enumerate(zip([4, 99, 4], [0, 7, 1])):
        np.testing.assert_array_equal(
            batched[row], np.asarray(sampler.stream_key(base, i, p))
        )
