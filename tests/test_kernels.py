"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
sweeping shapes and dtypes (CPU container; TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dapo_loss import dapo_loss
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import grouped_matmul, moe_expert_ffn
from repro.kernels.paged_attention import (
    paged_decode_attention,
    paged_decode_attention_update,
    paged_prefill_attention,
)

KEY = jax.random.PRNGKey(0)


def rnd(shape, dtype=jnp.float32, scale=1.0, salt=0):
    return (jax.random.normal(jax.random.fold_in(KEY, salt), shape) * scale).astype(dtype)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,hkv,hd,bq,bk",
    [
        (1, 128, 2, 2, 64, 64, 64),     # MHA
        (2, 256, 4, 2, 64, 128, 64),    # GQA 2:1
        (1, 256, 8, 2, 128, 64, 128),   # GQA 4:1, wide head
        (2, 128, 4, 1, 32, 128, 128),   # MQA, single block
    ],
)
def test_flash_attention_matches_ref(dtype, b, s, h, hkv, hd, bq, bk):
    q = rnd((b, s, h, hd), dtype, salt=1)
    k = rnd((b, s, hkv, hd), dtype, salt=2)
    v = rnd((b, s, hkv, hd), dtype, salt=3)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), **tol(dtype)
    )


@pytest.mark.parametrize("window", [32, 64])
def test_flash_attention_sliding_window(window):
    q, k, v = (rnd((1, 256, 4, 64), salt=i) for i in range(3))
    out = flash_attention(q, k, v, causal=True, window=window,
                          bq=64, bk=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_flash_attention_non_causal():
    q, k, v = (rnd((2, 128, 2, 64), salt=i + 7) for i in range(3))
    out = flash_attention(q, k, v, causal=False, bq=64, bk=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_flash_attention_cross_lengths():
    """Sq != Skv (cross attention / chunked prefill)."""
    q = rnd((1, 64, 4, 64), salt=11)
    k = rnd((1, 256, 4, 64), salt=12)
    v = rnd((1, 256, 4, 64), salt=13)
    out = flash_attention(q, k, v, causal=False, bq=64, bk=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_flash_attention_q_offset_decode_chunk():
    """Chunked prefill: queries continue at an offset into the KV."""
    full_q = rnd((1, 256, 2, 64), salt=21)
    k = rnd((1, 256, 2, 64), salt=22)
    v = rnd((1, 256, 2, 64), salt=23)
    out = flash_attention(full_q[:, 128:], k, v, causal=True, q_offset=128,
                          bq=64, bk=64, interpret=True)
    expect = ref.flash_attention_ref(full_q, k, v, causal=True)[:, 128:]
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------- decode attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,hkv,hd,bk",
    [
        (1, 128, 4, 4, 64, 64),
        (3, 256, 8, 2, 64, 64),
        (2, 512, 8, 1, 128, 128),
        (4, 256, 25, 5, 64, 256),      # hymba-style 5:1 GQA
    ],
)
def test_decode_attention_matches_ref(dtype, b, s, h, hkv, hd, bk):
    q = rnd((b, h, hd), dtype, salt=31)
    k = rnd((b, s, hkv, hd), dtype, salt=32)
    v = rnd((b, s, hkv, hd), dtype, salt=33)
    lengths = jnp.arange(1, b + 1) * (s // (b + 1)) + 1
    out = decode_attention(q, k, v, lengths.astype(jnp.int32), bk=bk, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), **tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_update_fused(dtype):
    """Fused decode + in-place ring write (the TPU answer to §Perf A1):
    output must equal where-update followed by plain decode attention, and
    the returned caches must contain exactly the written rows."""
    from repro.kernels.decode_attention import decode_attention_update

    b, s, h, hkv, hd, bk = 3, 256, 8, 2, 64, 64
    q = rnd((b, h, hd), dtype, salt=91)
    kc = rnd((b, s, hkv, hd), dtype, salt=92)
    vc = rnd((b, s, hkv, hd), dtype, salt=93)
    kn = rnd((b, hkv, hd), dtype, salt=94)
    vn = rnd((b, hkv, hd), dtype, salt=95)
    # append mid-cache, ring-overwrite slot 0, append at the last slot
    write_pos = jnp.array([100, 0, 255], jnp.int32)
    lengths = jnp.array([101, 256, 256], jnp.int32)
    # caches are donated (in-place on TPU) — pass copies, keep originals
    out, nk, nv = decode_attention_update(
        q, jnp.array(kc), jnp.array(vc), kn, vn, write_pos, lengths,
        bk=bk, interpret=True,
    )
    hit = (jnp.arange(s)[None, :] == write_pos[:, None])[..., None, None]
    ek = jnp.where(hit, kn[:, None], kc)
    ev = jnp.where(hit, vn[:, None], vc)
    expect = ref.decode_attention_ref(q, ek, ev, lengths)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), **tol(dtype)
    )
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(ek))
    np.testing.assert_array_equal(np.asarray(nv), np.asarray(ev))


def test_decode_attention_full_cache():
    b, s = 2, 256
    q = rnd((b, 4, 64), salt=41)
    k = rnd((b, s, 4, 64), salt=42)
    v = rnd((b, s, 4, 64), salt=43)
    lengths = jnp.full((b,), s, jnp.int32)
    out = decode_attention(q, k, v, lengths, bk=64, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


# ------------------------------------------------------ paged decode attention
def _mk_tables(b, nb, n_pool, salt=0):
    """Disjoint, shuffled block tables (block 0 reserved as the null sink)."""
    rng = np.random.RandomState(salt)
    blocks = rng.permutation(np.arange(1, n_pool))[: b * nb]
    return jnp.asarray(blocks.reshape(b, nb), jnp.int32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,n_pool,bs,nb,h,hkv,hd",
    [
        (2, 12, 64, 4, 4, 4, 64),     # MHA
        (3, 16, 32, 4, 8, 2, 64),     # GQA 4:1
        (2, 24, 128, 8, 8, 1, 128),   # MQA, wide head, long window
    ],
)
def test_paged_decode_attention_matches_ref(dtype, b, n_pool, bs, nb, h, hkv, hd):
    q = rnd((b, h, hd), dtype, salt=51)
    kp = rnd((n_pool, bs, hkv, hd), dtype, salt=52)
    vp = rnd((n_pool, bs, hkv, hd), dtype, salt=53)
    tables = _mk_tables(b, nb, n_pool, salt=54)
    lengths = jnp.arange(1, b + 1) * (nb * bs // (b + 1)) + 1
    out = paged_decode_attention(
        q, kp, vp, tables, lengths.astype(jnp.int32), interpret=True
    )
    expect = ref.paged_decode_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), **tol(dtype)
    )


def test_paged_decode_attention_matches_contiguous_dense():
    """An identity block table must reproduce plain decode attention over
    the same values laid out contiguously — the paged layout is purely an
    indirection."""
    b, s, bs, h, hkv, hd = 2, 256, 64, 8, 2, 64
    nb = s // bs
    kc = rnd((b, s, hkv, hd), salt=61)
    vc = rnd((b, s, hkv, hd), salt=62)
    q = rnd((b, h, hd), salt=63)
    # pool rows 1.. hold the dense rows' blocks in order
    kp = jnp.concatenate(
        [jnp.zeros((1, bs, hkv, hd)), kc.reshape(b * nb, bs, hkv, hd)]
    )
    vp = jnp.concatenate(
        [jnp.zeros((1, bs, hkv, hd)), vc.reshape(b * nb, bs, hkv, hd)]
    )
    tables = (jnp.arange(b * nb, dtype=jnp.int32) + 1).reshape(b, nb)
    lengths = jnp.array([100, 256], jnp.int32)
    out = paged_decode_attention(q, kp, vp, tables, lengths, interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("write_pos", [(0, 31, 63), (32, 95, 127)])
def test_paged_decode_attention_update_fused(dtype, write_pos):
    """Fused paged decode + pool block row write: output equals scatter-
    then-attend, and only the written rows of the pool change — including
    writes exactly at block boundaries."""
    b, n_pool, bs, nb, h, hkv, hd = 3, 14, 32, 4, 8, 2, 64
    q = rnd((b, h, hd), dtype, salt=71)
    kp = rnd((n_pool, bs, hkv, hd), dtype, salt=72)
    vp = rnd((n_pool, bs, hkv, hd), dtype, salt=73)
    kn = rnd((b, hkv, hd), dtype, salt=74)
    vn = rnd((b, hkv, hd), dtype, salt=75)
    tables = _mk_tables(b, nb, n_pool, salt=76)
    wp = jnp.asarray(write_pos, jnp.int32)
    # pools are donated (in-place on TPU) — pass copies, keep originals
    out, nk, nv = paged_decode_attention_update(
        q, jnp.array(kp), jnp.array(vp), kn, vn, tables, wp, interpret=True
    )
    expect, ek, ev = ref.paged_decode_attention_update_ref(
        q, kp, vp, kn, vn, tables, wp
    )
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), **tol(dtype)
    )
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(ek))
    np.testing.assert_array_equal(np.asarray(nv), np.asarray(ev))


# --------------------------------------------------- paged prefill attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,n_pool,bs,nb,sq,h,hkv,hd",
    [
        (2, 12, 64, 4, 64, 4, 4, 64),    # MHA, block-aligned suffix
        (3, 16, 32, 4, 48, 8, 2, 64),    # GQA 4:1, suffix spans blocks
        (2, 24, 128, 8, 128, 8, 1, 128), # MQA, wide head
    ],
)
def test_paged_prefill_attention_matches_ref(
    dtype, b, n_pool, bs, nb, sq, h, hkv, hd
):
    q = rnd((b, sq, h, hd), dtype, salt=161)
    kp = rnd((n_pool, bs, hkv, hd), dtype, salt=162)
    vp = rnd((n_pool, bs, hkv, hd), dtype, salt=163)
    tables = _mk_tables(b, nb, n_pool, salt=164)
    # each row starts its suffix mid-stream and ends mid-suffix: exercises
    # resident-prefix attention, the causal frontier, and padded q rows
    q_offsets = (jnp.arange(b) * bs // 2).astype(jnp.int32)
    lengths = (q_offsets + 1 + jnp.arange(b) * (sq // b) + sq // 2).astype(
        jnp.int32
    )
    out = paged_prefill_attention(
        q, kp, vp, tables, q_offsets, lengths, interpret=True
    )
    expect = ref.paged_prefill_attention_ref(
        q, kp, vp, tables, q_offsets, lengths
    )
    valid = (
        q_offsets[:, None] + jnp.arange(sq)[None] < lengths[:, None]
    )[..., None, None]
    np.testing.assert_allclose(
        jnp.where(valid, out, 0.0).astype(jnp.float32),
        jnp.where(valid, expect, 0.0).astype(jnp.float32),
        **tol(dtype),
    )


def test_paged_prefill_attention_matches_flash_contiguous():
    """A suffix window gathered through an identity block table must equal
    flash attention over the same values laid out contiguously, rows
    compared at the suffix positions (the paged layout is an indirection;
    equal window length makes the reductions identical)."""
    b, s, bs, h, hkv, hd = 2, 256, 64, 8, 2, 64
    nb = s // bs
    kc = rnd((b, s, hkv, hd), salt=171)
    vc = rnd((b, s, hkv, hd), salt=172)
    qc = rnd((b, s, h, hd), salt=173)
    kp = jnp.concatenate(
        [jnp.zeros((1, bs, hkv, hd)), kc.reshape(b * nb, bs, hkv, hd)]
    )
    vp = jnp.concatenate(
        [jnp.zeros((1, bs, hkv, hd)), vc.reshape(b * nb, bs, hkv, hd)]
    )
    tables = (jnp.arange(b * nb, dtype=jnp.int32) + 1).reshape(b, nb)
    suffix = 96
    q_offsets = jnp.array([s - suffix, s - suffix], jnp.int32)
    lengths = jnp.array([s - 32, s], jnp.int32)
    out = paged_prefill_attention(
        qc[:, s - suffix :], kp, vp, tables, q_offsets, lengths,
        interpret=True,
    )
    expect = ref.flash_attention_ref(qc, kc, vc, causal=True)
    valid = (
        q_offsets[:, None] + jnp.arange(suffix)[None] < lengths[:, None]
    )[..., None, None]
    np.testing.assert_allclose(
        jnp.where(valid, out, 0.0),
        jnp.where(valid, expect[:, s - suffix :], 0.0),
        atol=2e-5, rtol=2e-5,
    )


def test_paged_prefill_attention_ref_bitwise_flash_parity():
    """The ref op IS flash_attention_ref when the gathered window equals
    the contiguous length — bit-for-bit, not allclose. This is the
    contract the fork admission path relies on (the runner sizes block
    tables to the full-prefill bucket for exactly this reason)."""
    b, s, bs, h, hkv, hd = 1, 128, 32, 4, 2, 64
    nb = s // bs
    kc = rnd((b, s, hkv, hd), salt=181)
    vc = rnd((b, s, hkv, hd), salt=182)
    qc = rnd((b, s, h, hd), salt=183)
    kp = jnp.concatenate(
        [jnp.zeros((1, bs, hkv, hd)), kc.reshape(b * nb, bs, hkv, hd)]
    )
    vp = jnp.concatenate(
        [jnp.zeros((1, bs, hkv, hd)), vc.reshape(b * nb, bs, hkv, hd)]
    )
    tables = (jnp.arange(nb, dtype=jnp.int32) + 1).reshape(b, nb)
    lengths = jnp.array([s], jnp.int32)
    out = ref.paged_prefill_attention_ref(
        qc, kp, vp, tables, jnp.zeros((b,), jnp.int32), lengths
    )
    expect = ref.flash_attention_ref(qc, kc, vc, causal=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_paged_ops_dispatch():
    """ops.paged_* route ref and interpret impls to the same numbers."""
    b, n_pool, bs, nb, h, hkv, hd = 2, 10, 32, 4, 4, 2, 32
    q = rnd((b, h, hd), salt=81)
    kp = rnd((n_pool, bs, hkv, hd), salt=82)
    vp = rnd((n_pool, bs, hkv, hd), salt=83)
    tables = _mk_tables(b, nb, n_pool, salt=84)
    lengths = jnp.array([40, 128], jnp.int32)
    a = ops.paged_decode_attention(q, kp, vp, tables, lengths, impl="ref")
    c = ops.paged_decode_attention(
        q, kp, vp, tables, lengths, impl="interpret"
    )
    np.testing.assert_allclose(a, c, atol=2e-5, rtol=2e-5)
    qs = rnd((b, 32, h, hd), salt=85)
    q_off = jnp.array([8, 96], jnp.int32)
    pa = ops.paged_prefill_attention(
        qs, kp, vp, tables, q_off, lengths, impl="ref"
    )
    pc = ops.paged_prefill_attention(
        qs, kp, vp, tables, q_off, lengths, impl="interpret"
    )
    valid = (q_off[:, None] + jnp.arange(32)[None] < lengths[:, None])[
        ..., None, None
    ]
    np.testing.assert_allclose(
        jnp.where(valid, pa, 0.0), jnp.where(valid, pc, 0.0),
        atol=2e-5, rtol=2e-5,
    )


# --------------------------------------------------------------- block copy
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_copy_pool_blocks_matches_ref(dtype):
    """The CoW block-copy kernel: only dst blocks change, and they become
    exact copies of their src blocks in both pools."""
    l, n, bs, hkv, hd = 3, 8, 16, 2, 32
    kp = rnd((l, n, bs, hkv, hd), dtype, salt=91)
    vp = rnd((l, n, bs, hkv, hd), dtype, salt=92)
    src = jnp.array([1, 1, 5], jnp.int32)   # one src fans out to two dsts
    dst = jnp.array([3, 6, 2], jnp.int32)
    rk, rv = ops.copy_pool_blocks(kp, vp, src, dst, impl="ref")
    # the pallas path donates the pools (in-place block move); hand it
    # copies so the originals stay comparable
    ik, iv = ops.copy_pool_blocks(
        jnp.array(kp), jnp.array(vp), src, dst, impl="interpret"
    )
    for got_k, got_v in ((rk, rv), (ik, iv)):
        np.testing.assert_array_equal(np.asarray(got_k), np.asarray(rk))
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(rv))
        for s, d in zip((1, 1, 5), (3, 6, 2)):
            np.testing.assert_array_equal(
                np.asarray(got_k[:, d]), np.asarray(kp[:, s])
            )
            np.testing.assert_array_equal(
                np.asarray(got_v[:, d]), np.asarray(vp[:, s])
            )
        untouched = [i for i in range(n) if i not in (3, 6, 2)]
        np.testing.assert_array_equal(
            np.asarray(got_k[:, untouched]), np.asarray(kp[:, untouched])
        )


def test_copy_pool_blocks_null_padding_is_harmless():
    """Padded copies aimed at the null garbage block leave every real
    block intact (the runner pads copy batches to a power of two)."""
    l, n, bs, hkv, hd = 2, 6, 8, 1, 16
    kp = rnd((l, n, bs, hkv, hd), salt=93)
    vp = rnd((l, n, bs, hkv, hd), salt=94)
    src = jnp.array([2, 0, 0, 0], jnp.int32)
    dst = jnp.array([4, 0, 0, 0], jnp.int32)
    nk, nv = ops.copy_pool_blocks(kp, vp, src, dst, impl="ref")
    np.testing.assert_array_equal(np.asarray(nk[:, 4]), np.asarray(kp[:, 2]))
    real = [1, 2, 3, 5]
    np.testing.assert_array_equal(
        np.asarray(nk[:, real]), np.asarray(kp[:, real])
    )
    np.testing.assert_array_equal(
        np.asarray(nv[:, real]), np.asarray(vp[:, real])
    )


# -------------------------------------------------------------------- MoE GMM
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "e,c,d,f",
    [(2, 128, 128, 128), (4, 128, 256, 512), (8, 256, 128, 384)],
)
def test_grouped_matmul_matches_einsum(dtype, e, c, d, f):
    x = rnd((e, c, d), dtype, 0.1, salt=51)
    w = rnd((e, d, f), dtype, 0.1, salt=52)
    out = grouped_matmul(x, w, interpret=True)
    expect = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32))
    np.testing.assert_allclose(out, expect, **tol(dtype))


def test_moe_expert_ffn_matches_ref():
    e, c, d, f = 4, 128, 256, 512
    x = rnd((e, c, d), scale=0.1, salt=61)
    wg = rnd((e, d, f), scale=0.05, salt=62)
    wu = rnd((e, d, f), scale=0.05, salt=63)
    wd = rnd((e, f, d), scale=0.05, salt=64)
    out = moe_expert_ffn(x, wg, wu, wd, interpret=True)
    expect = ref.moe_gmm_ref(x, wg, wu, wd)
    np.testing.assert_allclose(out, expect, atol=1e-4, rtol=1e-4)


# -------------------------------------------------------------- selective scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,i,n,bi", [(2, 64, 256, 16, 128), (1, 32, 128, 8, 128)])
def test_selective_scan_matches_ref(dtype, b, s, i, n, bi):
    """Fused Mamba recurrence: the (B,S,I,N) state tensors never hit HBM."""
    from repro.kernels.selective_scan import selective_scan, selective_scan_ref

    dt = jax.nn.softplus(rnd((b, s, i), salt=101) - 3).astype(dtype)
    x = rnd((b, s, i), dtype, 0.5, salt=102)
    bm = rnd((b, s, n), dtype, 0.5, salt=103)
    cm = rnd((b, s, n), dtype, 0.5, salt=104)
    a = -jnp.exp(rnd((i, n), scale=0.3, salt=105))
    h0 = rnd((b, i, n), scale=0.1, salt=106)
    y, hf = selective_scan(dt, x, bm, cm, a, h0, bi=bi, interpret=True)
    ey, ehf = selective_scan_ref(dt, x, bm, cm, a, h0)
    np.testing.assert_allclose(
        y.astype(jnp.float32), ey.astype(jnp.float32), **tol(dtype)
    )
    np.testing.assert_allclose(hf, ehf, **tol(dtype))


def test_mamba_block_interpret_matches_ref_path():
    """The hybrid block produces identical outputs via the XLA chunked path
    and the fused Pallas selective-scan path."""
    import jax as _jax
    from repro.models import layers as L

    key = _jax.random.PRNGKey(3)
    d, inner, n, w, b, s = 64, 128, 8, 4, 2, 32
    p = {
        "w_in": _jax.random.normal(key, (d, 2 * inner)) * 0.1,
        "w_out": _jax.random.normal(_jax.random.fold_in(key, 1), (inner, d)) * 0.1,
        "conv_w": _jax.random.normal(_jax.random.fold_in(key, 2), (w, inner)) * 0.2,
        "w_bc": _jax.random.normal(_jax.random.fold_in(key, 3), (inner, 2 * n)) * 0.2,
        "w_dt": jnp.full((inner,), 0.05),
        "a_log": jnp.log(jnp.broadcast_to(jnp.arange(1.0, n + 1), (inner, n))),
        "d_skip": jnp.ones((inner,)),
        "dt_bias": jnp.full((inner,), -4.6),
    }
    x = _jax.random.normal(_jax.random.fold_in(key, 4), (b, s, d)) * 0.3
    y_ref, (c_ref, s_ref) = L.mamba_block(x, p, impl="ref")
    y_plk, (c_plk, s_plk) = L.mamba_block(x, p, impl="interpret")
    np.testing.assert_allclose(y_ref, y_plk, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(s_ref, s_plk, atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------ DAPO loss
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,bb,bt", [(8, 512, 8, 512), (16, 1024, 8, 256)])
def test_dapo_loss_matches_ref(dtype, b, t, bb, bt):
    lp = (rnd((b, t), scale=0.1, salt=71) - 2.0).astype(dtype)
    olp = (lp.astype(jnp.float32) + rnd((b, t), scale=0.05, salt=72)).astype(dtype)
    adv = rnd((b,), salt=73)
    mask = (jax.random.uniform(jax.random.fold_in(KEY, 74), (b, t)) > 0.3).astype(jnp.float32)
    loss, ratio = dapo_loss(lp, olp, adv, mask, bb=bb, bt=bt, interpret=True)
    eloss, eratio = ref.dapo_loss_ref(lp, olp, adv, mask)
    np.testing.assert_allclose(loss, eloss, **tol(dtype))
    np.testing.assert_allclose(ratio, eratio, **tol(dtype))


def test_dapo_loss_clip_higher_asymmetry():
    """DAPO's eps_high > eps_low: upside ratios clip later than downside."""
    lp = jnp.log(jnp.full((1, 4), 0.5))
    olp = jnp.log(jnp.full((1, 4), 0.4))       # ratio = 1.25
    adv = jnp.ones((1,))
    mask = jnp.ones((1, 4))
    loss_sym, _ = ref.dapo_loss_ref(lp, olp, adv, mask, eps_low=0.2, eps_high=0.2)
    loss_dapo, _ = ref.dapo_loss_ref(lp, olp, adv, mask, eps_low=0.2, eps_high=0.28)
    assert loss_dapo < loss_sym  # higher clip ceiling -> larger kept objective


# ------------------------------------------------------------------- dispatch
def test_ops_dispatch_ref_equals_interpret():
    q, k, v = (rnd((1, 128, 2, 64), salt=81 + i) for i in range(3))
    a = ops.flash_attention(q, k, v, impl="ref")
    b = ops.flash_attention(q, k, v, impl="interpret")
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_ops_default_is_ref_on_cpu():
    assert ops.resolve_impl() == "ref"
    ops.set_default_impl("interpret")
    try:
        assert ops.resolve_impl() == "interpret"
    finally:
        ops.set_default_impl(None)
