"""KV block allocator: leak-proof invariants under arbitrary op sequences.

The allocator backs the paged engine's admission/extend/free lifecycle, so
a leaked or double-owned block silently shrinks (or corrupts) replica
capacity. Every test drives random or adversarial op sequences and asserts
the pool invariants (``BlockAllocator.check``) after every mutation.
"""
import numpy as np
import pytest

from tests._optional import given, settings, st
from repro.rollout.kv_allocator import (
    NULL_BLOCK,
    BlockAllocator,
    BlockExhausted,
    blocks_for_tokens,
)


def test_blocks_for_tokens():
    assert blocks_for_tokens(0, 16) == 0
    assert blocks_for_tokens(1, 16) == 1
    assert blocks_for_tokens(16, 16) == 1
    assert blocks_for_tokens(17, 16) == 2


def test_alloc_extend_free_roundtrip():
    a = BlockAllocator(9, 16)  # 8 allocatable + null
    t = a.alloc(1, 20)         # 2 blocks
    assert len(t) == 2 and a.used_blocks == 2
    assert a.capacity(1) == 32
    assert NULL_BLOCK not in t
    assert a.extend_to(1, 30) == []          # already covered
    new = a.extend_to(1, 33)                 # 3rd block
    assert len(new) == 1 and a.capacity(1) == 48
    assert a.free(1) == 3
    assert a.used_blocks == 0 and a.n_free == 8
    a.check()


def test_exhaustion_allocates_nothing():
    a = BlockAllocator(4, 8)   # 3 allocatable
    a.alloc(1, 16)             # 2 blocks
    with pytest.raises(BlockExhausted):
        a.alloc(2, 17)         # needs 3, only 1 free
    a.check()
    assert a.used_blocks == 2  # failed alloc left no partial allocation
    with pytest.raises(BlockExhausted):
        a.extend_to(1, 33)     # needs 2 more, only 1 free
    assert a.capacity(1) == 16


def test_double_free_and_double_alloc_fail_loudly():
    a = BlockAllocator(4, 8)
    a.alloc(7, 8)
    with pytest.raises(ValueError):
        a.alloc(7, 8)
    a.free(7)
    with pytest.raises(KeyError):
        a.free(7)
    a.check()


def _apply(a: BlockAllocator, live: dict, op: int, owner: int, tokens: int):
    """One randomized lifecycle op against the allocator + a shadow model."""
    if op == 0:  # admit
        if owner in live:
            return
        try:
            a.alloc(owner, tokens)
            live[owner] = tokens
        except BlockExhausted:
            pass
    elif op == 1:  # decode growth
        if owner in live:
            try:
                a.extend_to(owner, live[owner] + tokens)
                live[owner] += tokens
            except BlockExhausted:
                pass
    else:  # finish / interrupt / abort / preempt all free the table
        if owner in live:
            a.free(owner)
            del live[owner]


def _check_model(a: BlockAllocator, live: dict):
    a.check()
    assert set(a.owners()) == set(live)
    for owner, tokens in live.items():
        assert a.capacity(owner) >= tokens
        assert len(a.table(owner)) == blocks_for_tokens(tokens, a.block_size)


def test_randomized_lifecycle_never_leaks():
    """np.random stress (runs offline, where hypothesis is unavailable)."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        a = BlockAllocator(int(rng.integers(2, 24)), int(rng.integers(1, 20)))
        live: dict = {}
        for _ in range(200):
            _apply(
                a, live,
                op=int(rng.integers(0, 3)),
                owner=int(rng.integers(0, 8)),
                tokens=int(rng.integers(1, 64)),
            )
            _check_model(a, live)
        for owner in list(live):
            a.free(owner)
        a.check()
        assert a.used_blocks == 0


@settings(max_examples=200, deadline=None)
@given(
    n_blocks=st.integers(2, 24),
    block_size=st.integers(1, 20),
    ops=st.lists(
        st.tuples(
            st.integers(0, 2),    # admit / extend / release
            st.integers(0, 7),    # owner
            st.integers(1, 64),   # token count
        ),
        max_size=120,
    ),
)
def test_property_no_leak_no_double_free(n_blocks, block_size, ops):
    a = BlockAllocator(n_blocks, block_size)
    live: dict = {}
    for op, owner, tokens in ops:
        _apply(a, live, op, owner, tokens)
        _check_model(a, live)
    for owner in list(live):
        a.free(owner)
    a.check()
    assert a.used_blocks == 0 and a.n_free == n_blocks - 1
