"""KV block allocator: leak-proof invariants under arbitrary op sequences.

The allocator backs the paged engine's admission/extend/free lifecycle, so
a leaked or double-owned block silently shrinks (or corrupts) replica
capacity. Every test drives random or adversarial op sequences and asserts
the pool invariants (``BlockAllocator.check``) after every mutation.
"""
import numpy as np
import pytest

from tests._optional import given, settings, st
from repro.rollout.kv_allocator import (
    NULL_BLOCK,
    BlockAllocator,
    BlockExhausted,
    blocks_for_tokens,
)
from repro.rollout.prefix_cache import RefcountedBlockAllocator


def test_blocks_for_tokens():
    assert blocks_for_tokens(0, 16) == 0
    assert blocks_for_tokens(1, 16) == 1
    assert blocks_for_tokens(16, 16) == 1
    assert blocks_for_tokens(17, 16) == 2


def test_alloc_extend_free_roundtrip():
    a = BlockAllocator(9, 16)  # 8 allocatable + null
    t = a.alloc(1, 20)         # 2 blocks
    assert len(t) == 2 and a.used_blocks == 2
    assert a.capacity(1) == 32
    assert NULL_BLOCK not in t
    assert a.extend_to(1, 30) == []          # already covered
    new = a.extend_to(1, 33)                 # 3rd block
    assert len(new) == 1 and a.capacity(1) == 48
    assert a.free(1) == 3
    assert a.used_blocks == 0 and a.n_free == 8
    a.check()


def test_exhaustion_allocates_nothing():
    a = BlockAllocator(4, 8)   # 3 allocatable
    a.alloc(1, 16)             # 2 blocks
    with pytest.raises(BlockExhausted):
        a.alloc(2, 17)         # needs 3, only 1 free
    a.check()
    assert a.used_blocks == 2  # failed alloc left no partial allocation
    with pytest.raises(BlockExhausted):
        a.extend_to(1, 33)     # needs 2 more, only 1 free
    assert a.capacity(1) == 16


def test_double_free_and_double_alloc_fail_loudly():
    a = BlockAllocator(4, 8)
    a.alloc(7, 8)
    with pytest.raises(ValueError):
        a.alloc(7, 8)
    a.free(7)
    with pytest.raises(KeyError):
        a.free(7)
    a.check()


def _apply(a: BlockAllocator, live: dict, op: int, owner: int, tokens: int):
    """One randomized lifecycle op against the allocator + a shadow model."""
    if op == 0:  # admit
        if owner in live:
            return
        try:
            a.alloc(owner, tokens)
            live[owner] = tokens
        except BlockExhausted:
            pass
    elif op == 1:  # decode growth
        if owner in live:
            try:
                a.extend_to(owner, live[owner] + tokens)
                live[owner] += tokens
            except BlockExhausted:
                pass
    else:  # finish / interrupt / abort / preempt all free the table
        if owner in live:
            a.free(owner)
            del live[owner]


def _check_model(a: BlockAllocator, live: dict):
    a.check()
    assert set(a.owners()) == set(live)
    for owner, tokens in live.items():
        assert a.capacity(owner) >= tokens
        assert len(a.table(owner)) == blocks_for_tokens(tokens, a.block_size)


def test_randomized_lifecycle_never_leaks():
    """np.random stress (runs offline, where hypothesis is unavailable)."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        a = BlockAllocator(int(rng.integers(2, 24)), int(rng.integers(1, 20)))
        live: dict = {}
        for _ in range(200):
            _apply(
                a, live,
                op=int(rng.integers(0, 3)),
                owner=int(rng.integers(0, 8)),
                tokens=int(rng.integers(1, 64)),
            )
            _check_model(a, live)
        for owner in list(live):
            a.free(owner)
        a.check()
        assert a.used_blocks == 0


@settings(max_examples=200, deadline=None)
@given(
    n_blocks=st.integers(2, 24),
    block_size=st.integers(1, 20),
    ops=st.lists(
        st.tuples(
            st.integers(0, 2),    # admit / extend / release
            st.integers(0, 7),    # owner
            st.integers(1, 64),   # token count
        ),
        max_size=120,
    ),
)
def test_property_no_leak_no_double_free(n_blocks, block_size, ops):
    a = BlockAllocator(n_blocks, block_size)
    live: dict = {}
    for op, owner, tokens in ops:
        _apply(a, live, op, owner, tokens)
        _check_model(a, live)
    for owner in list(live):
        a.free(owner)
    a.check()
    assert a.used_blocks == 0 and a.n_free == n_blocks - 1


# ===================================================== refcounted (sharing)
# The prefix-sharing layer: blocks may appear in several owners' tables
# with a refcount; frees decrement and return a block only at zero. The
# group-admission op allocates a prompt's full blocks once for the whole
# group plus a private tail per member.

def test_refcounted_group_alloc_shares_full_blocks():
    a = RefcountedBlockAllocator(32, 16)
    shared, tails = a.alloc_group([1, 2, 3, 4], 37)  # 2 full + 5-token tail
    assert len(shared) == 2 and len(tails) == 4
    assert a.used_blocks == 2 + 4              # full blocks stored ONCE
    assert all(a.refcount(b) == 4 for b in shared)
    assert all(a.refcount(b) == 1 for b in tails)
    for i, owner in enumerate((1, 2, 3, 4)):
        assert a.table(owner) == shared + [tails[i]]
        assert a.capacity(owner) == 48
    assert a.shared_blocks == 2
    assert a.shared_tokens() == 3 * 2 * 16     # what dense would cost extra
    a.check()


def test_refcounted_group_alloc_block_aligned_prompt_has_no_tail():
    a = RefcountedBlockAllocator(16, 8)
    shared, tails = a.alloc_group([1, 2, 3], 24)
    assert len(shared) == 3 and tails == []
    assert a.used_blocks == 3
    # each member grows with private blocks from there
    new = a.extend_to(2, 25)
    assert len(new) == 1 and a.refcount(new[0]) == 1
    a.check()


def test_refcounted_free_releases_shared_blocks_last_owner_only():
    a = RefcountedBlockAllocator(32, 16)
    shared, tails = a.alloc_group([1, 2, 3], 37)
    assert a.free(1) == 1                # only its private tail
    assert all(a.refcount(b) == 2 for b in shared)
    assert a.used_blocks == 2 + 2
    assert a.free(2) == 1
    assert a.free(3) == 1 + 2            # last owner returns the prefix too
    assert a.used_blocks == 0 and a.n_free == 31
    a.check()


def test_refcounted_fork_joins_existing_prefix():
    a = RefcountedBlockAllocator(32, 16)
    shared, _ = a.alloc_group([1, 2], 32)
    own = a.fork(9, shared, 40)
    assert len(own) == 1
    assert all(a.refcount(b) == 3 for b in shared)
    assert a.table(9) == shared + own
    with pytest.raises(ValueError):
        a.fork(9, shared, 40)            # owner already exists
    with pytest.raises(ValueError):
        a.fork(10, [31], 32)             # sharing an unowned block
    a.check()


def test_refcounted_group_alloc_atomic_on_exhaustion():
    a = RefcountedBlockAllocator(5, 16)  # 4 allocatable
    with pytest.raises(BlockExhausted):
        a.alloc_group([1, 2, 3, 4], 17)  # needs 1 shared + 4 tails
    a.check()
    assert a.used_blocks == 0
    with pytest.raises(ValueError):
        a.alloc_group([1, 1], 8)         # duplicate owners
    a.check()


def test_refcounted_exclusive_use_matches_base_allocator():
    """Without sharing, the refcounted pool is the plain pool."""
    a, b = RefcountedBlockAllocator(9, 16), BlockAllocator(9, 16)
    for alloc in (a, b):
        alloc.alloc(1, 20)
        alloc.extend_to(1, 40)
        alloc.alloc(2, 5)
        alloc.free(1)
    assert a.used_blocks == b.used_blocks
    assert a.n_free == b.n_free
    assert a.table(2) == b.table(2)
    a.check(), b.check()


def _apply_ref(a: RefcountedBlockAllocator, live: dict, op: int,
               owner: int, tokens: int, group: int):
    """One randomized lifecycle op against the refcounted allocator and a
    shadow model. ``live`` maps owner -> covered tokens. Ops: admit /
    extend / release (as the base allocator) plus group-admit (share) and
    fork (join the last surviving group's prefix)."""
    if op == 0:  # admit (exclusive)
        if owner in live:
            return
        try:
            a.alloc(owner, tokens)
            live[owner] = tokens
        except BlockExhausted:
            pass
    elif op == 1:  # decode growth
        if owner in live:
            try:
                a.extend_to(owner, live[owner] + tokens)
                live[owner] += tokens
            except BlockExhausted:
                pass
    elif op == 2:  # finish / interrupt / abort / preempt free the table
        if owner in live:
            a.free(owner)
            del live[owner]
    elif op == 3:  # group admission (prefix sharing)
        owners = [owner * 10 + i for i in range(group)]
        if any(o in live for o in owners):
            return
        try:
            a.alloc_group(owners, tokens)
            for o in owners:
                live[o] = tokens
        except BlockExhausted:
            pass
    else:  # fork off some live owner's full prefix blocks
        if owner in live or not live:
            return
        src = sorted(live)[0]
        bs = a.block_size
        shared = a.table(src)[: live[src] // bs]
        want = len(shared) * bs + (tokens % (2 * bs))
        try:
            a.fork(owner, shared, want)
            live[owner] = want
        except BlockExhausted:
            pass


def _check_ref_model(a: RefcountedBlockAllocator, live: dict):
    a.check()
    assert set(a.owners()) == set(live)
    for owner, tokens in live.items():
        assert a.capacity(owner) >= tokens
    # distinct accounting never exceeds per-owner sums
    per_owner = sum(len(a.table(o)) for o in live)
    assert a.used_blocks <= per_owner


def test_refcounted_randomized_lifecycle_never_leaks():
    """np.random stress (runs offline, where hypothesis is unavailable)."""
    rng = np.random.default_rng(1)
    for _ in range(20):
        a = RefcountedBlockAllocator(
            int(rng.integers(2, 32)), int(rng.integers(1, 20))
        )
        live: dict = {}
        for _ in range(200):
            _apply_ref(
                a, live,
                op=int(rng.integers(0, 5)),
                owner=int(rng.integers(0, 8)),
                tokens=int(rng.integers(1, 64)),
                group=int(rng.integers(2, 5)),
            )
            _check_ref_model(a, live)
        for owner in list(live):
            a.free(owner)
        a.check()
        assert a.used_blocks == 0


@settings(max_examples=200, deadline=None)
@given(
    n_blocks=st.integers(2, 32),
    block_size=st.integers(1, 20),
    ops=st.lists(
        st.tuples(
            st.integers(0, 4),    # admit / extend / release / share / fork
            st.integers(0, 7),    # owner
            st.integers(1, 64),   # token count
            st.integers(2, 5),    # group size for share ops
        ),
        max_size=120,
    ),
)
def test_property_refcounted_no_leak_no_double_free(
    n_blocks, block_size, ops
):
    """Refcount/free-list invariants hold under random share/fork/extend/
    free/preempt interleavings; draining every owner leaves a full pool."""
    a = RefcountedBlockAllocator(n_blocks, block_size)
    live: dict = {}
    for op, owner, tokens, group in ops:
        _apply_ref(a, live, op, owner, tokens, group)
        _check_ref_model(a, live)
    for owner in list(live):
        a.free(owner)
    a.check()
    assert a.used_blocks == 0 and a.n_free == n_blocks - 1
