"""Service layer unit tests: lifecycle bus, retired-payload store, reward
server (inline + threaded), and the TS-as-subscriber wiring."""
import threading
import time

from repro.core import (
    FnVerifier,
    RetiredPayloadStore,
    RewardServer,
    RewardServerConfig,
    TrajectoryLifecycle,
    TrajectoryServer,
)
from repro.core.lifecycle import LifecycleEventKind as K
from repro.core.types import Trajectory, TrajStatus, reset_traj_ids


def mk_traj(tid, prompt=(1, 2, 3)):
    return Trajectory(traj_id=tid, prompt=list(prompt))


# ------------------------------------------------------------------- the bus
def test_bus_dispatches_in_subscription_order_and_counts():
    bus = TrajectoryLifecycle()
    order = []
    bus.subscribe(K.COMPLETED, lambda e: order.append(("a", e.traj_id)))
    bus.subscribe(K.COMPLETED, lambda e: order.append(("b", e.traj_id)))
    bus.subscribe(K.REWARDED, lambda e: order.append(("r", e.traj_id)))
    t = mk_traj(7)
    bus.completed(t, inst=0)
    bus.rewarded(t)
    assert order == [("a", 7), ("b", 7), ("r", 7)]
    assert bus.counts[K.COMPLETED] == 1
    assert bus.counts[K.REWARDED] == 1
    assert bus.counts[K.ABORTED] == 0


def test_bus_reentrant_emit_from_handler():
    """Surplus aborts cascade off REWARDED — emitting inside a handler must
    not deadlock or drop events."""
    bus = TrajectoryLifecycle()
    seen = []
    bus.subscribe(K.REWARDED, lambda e: bus.aborted(e.traj_id + 1))
    bus.subscribe(K.ABORTED, lambda e: seen.append(e.traj_id))
    bus.rewarded(mk_traj(10))
    assert seen == [11]
    assert bus.counts[K.ABORTED] == 1


def test_bus_concurrent_emitters_do_not_deadlock():
    """Regression: dispatch must not hold a global bus lock — handlers take
    domain locks, and two services emitting concurrently used to deadlock
    (reward worker in REWARDED->coordinator-lock vs coordinator holding its
    lock emitting INTERRUPTED)."""
    bus = TrajectoryLifecycle()
    lock_a, lock_b = threading.Lock(), threading.Lock()
    entered = threading.Barrier(2, timeout=5)

    def sub_rewarded(e):  # takes A
        entered.wait()
        with lock_a:
            time.sleep(0.01)

    def sub_aborted(e):  # takes B
        entered.wait()
        with lock_b:
            time.sleep(0.01)

    bus.subscribe(K.REWARDED, sub_rewarded)
    bus.subscribe(K.ABORTED, sub_aborted)
    t1 = threading.Thread(target=lambda: bus.rewarded(mk_traj(1)))
    t2 = threading.Thread(target=lambda: bus.aborted(2))
    t1.start()
    t2.start()
    t1.join(timeout=5)
    t2.join(timeout=5)
    assert not t1.is_alive() and not t2.is_alive()


def test_unsubscribe():
    bus = TrajectoryLifecycle()
    hits = []
    fn = bus.subscribe(K.CONSUMED, lambda e: hits.append(e.traj_id))
    bus.consumed(1)
    bus.unsubscribe(K.CONSUMED, fn)
    bus.consumed(2)
    assert hits == [1]


# --------------------------------------------------------------- the store
def test_retired_store_retains_until_taken_and_evicts_on_abort():
    bus = TrajectoryLifecycle()
    store = RetiredPayloadStore(bus)
    a, b = mk_traj(1), mk_traj(2)
    bus.rewarded(a)
    bus.rewarded(b)
    assert len(store) == 2
    # group filtering threw b away whole-group: no leak
    bus.aborted(2)
    assert store.ids() == [1]
    got = store.take([1, 2, 3])  # missing ids skipped (pop-if-present)
    assert got == [a]
    assert len(store) == 0


# ---------------------------------------------------------- trajectory server
def _mk_ts(n=4):
    prompts = iter([[1, 2]] * n)
    return TrajectoryServer(prompts, capacity_groups=n, group_size=1)


def test_ts_attach_applies_transitions_from_events():
    reset_traj_ids()
    bus = TrajectoryLifecycle()
    ts = _mk_ts()
    ts.attach(bus)
    ts.refill()
    t = ts.peek()[0]
    ts.take(t.traj_id)
    assert t.status == TrajStatus.RUNNING
    bus.interrupted(t)
    assert t.status == TrajStatus.INTERRUPTED and t.traj_id in [
        x.traj_id for x in ts.peek()
    ]
    ts.take(t.traj_id)
    bus.completed(t)
    assert t.status == TrajStatus.GENERATED
    bus.consumed(t.traj_id)
    assert ts.get(t.traj_id) is None
    # events for dropped trajectories are tolerated (abort races)
    bus.completed(t)
    bus.interrupted(t)
    other = ts.peek()[0]
    bus.aborted(other.traj_id)
    assert ts.get(other.traj_id) is None


# ------------------------------------------------------------- reward server
def test_reward_server_inline_scores_synchronously():
    bus = TrajectoryLifecycle()
    rewarded = []
    rs = RewardServer(FnVerifier(lambda p, r: float(len(r))), bus)
    bus.subscribe(K.REWARDED, lambda e: rewarded.append(e.traj_id))
    t = mk_traj(5)
    t.response = [9, 9, 9]
    bus.completed(t, inst=0)
    # inline mode: by the time emit returns, the reward landed
    assert t.reward == 3.0
    assert rewarded == [5]
    assert rs.stats()["scored"] == 1


def test_reward_server_threaded_pool_scores_all_and_reports_latency():
    bus = TrajectoryLifecycle()
    done = []
    rs = RewardServer(
        FnVerifier(lambda p, r: 1.0),
        bus,
        RewardServerConfig(n_workers=3, queue_capacity=8,
                           simulated_latency=0.002),
    )
    bus.subscribe(K.REWARDED, lambda e: done.append(e.traj_id))
    rs.start()
    try:
        trajs = [mk_traj(100 + i) for i in range(12)]
        for t in trajs:
            bus.completed(t, inst=0)
        assert rs.drain(timeout=30.0)
    finally:
        rs.stop()
    assert sorted(done) == [100 + i for i in range(12)]
    assert all(t.reward == 1.0 for t in trajs)
    pct = rs.latency_percentiles((0.5, 0.99))
    assert pct[0.5] is not None and pct[0.5] >= 0.002
    assert rs.stats()["scored"] == 12


def test_reward_server_drops_aborted_while_queued():
    """A trajectory aborted between completion and scoring must be dropped
    at the liveness gate, never scored or published REWARDED."""
    bus = TrajectoryLifecycle()
    alive = {1}
    rewarded = []
    rs = RewardServer(
        FnVerifier(lambda p, r: 1.0), bus,
        liveness=lambda t: t.traj_id in alive,
    )
    bus.subscribe(K.REWARDED, lambda e: rewarded.append(e.traj_id))
    live, dead = mk_traj(1), mk_traj(2)
    bus.completed(live, inst=0)
    bus.completed(dead, inst=0)   # not in `alive`: aborted while queued
    assert rewarded == [1]
    assert dead.reward is None
    s = rs.stats()
    assert s["scored"] == 1 and s["dropped"] == 1


def test_retired_store_skips_payloads_already_aborted():
    """REWARDED arriving after the trajectory's ABORTED (late reward-queue
    race) must not re-insert the evicted payload."""
    bus = TrajectoryLifecycle()
    store = RetiredPayloadStore(bus)
    t = mk_traj(4)
    t.status = TrajStatus.ABORTED  # ts.drop already ran
    bus.rewarded(t)
    assert len(store) == 0
