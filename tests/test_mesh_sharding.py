"""Mesh construction + rollout sharding-spec helpers.

Spec rules are pure functions of mesh *shape* — tested directly with a
duck-typed mesh (the tests/test_distributed.py pattern). Everything that
needs real devices (mesh construction, NamedSharding placement of the
paged pool, per-device shard shapes) runs in subprocesses under forced
host device counts.
"""

import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    ROLLOUT_AXIS,
    paged_pool_spec,
    rollout_param_spec,
)


class FakeMesh:
    """Duck-typed mesh: only ``shape`` (axis sizes) is consulted."""

    def __init__(self, **axes):
        self.shape = dict(axes)


MESH = FakeMesh(tensor=4)


# ------------------------------------------------------------ param specs
def test_rollout_param_spec_attention_projections_head_sharded():
    # stacked (L, D, H*hd): output heads -> tensor, contraction dim whole
    assert rollout_param_spec(MESH, "['blocks']['wq']", (4, 64, 64)) == P(
        None, None, ROLLOUT_AXIS
    )
    assert rollout_param_spec(MESH, "['blocks']['wk']", (4, 64, 32)) == P(
        None, None, ROLLOUT_AXIS
    )
    assert rollout_param_spec(MESH, "['blocks']['bq']", (4, 64)) == P(
        None, ROLLOUT_AXIS
    )


def test_rollout_param_spec_reduction_side_replicated():
    # weights consumed by a full-width contraction never shard
    assert rollout_param_spec(MESH, "['blocks']['wo']", (4, 64, 64)) == P()
    assert rollout_param_spec(MESH, "['blocks']['w_down']", (4, 128, 64)) == P()
    assert rollout_param_spec(MESH, "['embed']", (256, 64)) == P()
    assert rollout_param_spec(MESH, "['blocks']['attn_norm']", (4, 64)) == P()


def test_rollout_param_spec_ffn_and_lm_head_column_sharded():
    assert rollout_param_spec(MESH, "['blocks']['w_gate']", (4, 64, 128)) == P(
        None, None, ROLLOUT_AXIS
    )
    assert rollout_param_spec(MESH, "['lm_head']", (64, 256)) == P(None, ROLLOUT_AXIS)


def test_rollout_param_spec_nondivisible_falls_back_to_replication():
    # output dim 30 does not divide tensor=4 -> that dim replicates
    assert rollout_param_spec(MESH, "['blocks']['wq']", (4, 64, 30)) == P(
        None, None, None
    )


# ------------------------------------------------------------- pool specs
def test_paged_pool_spec_shards_kv_head_axis():
    spec = paged_pool_spec(MESH, (4, 33, 8, 4, 16))
    assert spec == P(None, None, None, ROLLOUT_AXIS, None)


def test_paged_pool_spec_nondivisible_heads_replicate():
    assert paged_pool_spec(MESH, (4, 33, 8, 3, 16)) == P(None, None, None, None, None)


def test_paged_pool_spec_rejects_wrong_rank():
    with pytest.raises(ValueError, match="rank 5"):
        paged_pool_spec(MESH, (33, 8, 4, 16))


# ------------------------------------------------------------- subprocess
def _run_subprocess(code: str, devices: int = 8) -> str:
    prog = (
        f"import os; os.environ['XLA_FLAGS']="
        f"'--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(code)
    )
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        text=True,
        timeout=480,
        env={
            "PYTHONPATH": "src",
            "PATH": "/usr/bin:/bin",
            # forward so the child never probes for a TPU backend
            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
        },
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_make_rollout_mesh_shapes_subprocess():
    out = _run_subprocess(
        """
        import jax

        from repro.launch.mesh import make_rollout_mesh

        m = make_rollout_mesh(4)
        assert m.shape == {"tensor": 4}, m.shape
        assert m.size == 4
        m8 = make_rollout_mesh(8)
        assert m8.shape == {"tensor": 8}
        try:
            make_rollout_mesh(16)
        except ValueError as e:
            assert "xla_force_host_platform_device_count" in str(e)
        else:
            raise AssertionError("16 > 8 devices must raise")
        print("MESH_OK")
        """,
        devices=8,
    )
    assert "MESH_OK" in out


def test_paged_cache_shardings_placement_subprocess():
    """Placing a real paged cache on a 4-way mesh: K/V pools split on the
    KV-head axis (per-device shard = Hkv/4 heads), per-slot small state
    replicated — for the dense and hybrid (conv/ssm state) families."""
    out = _run_subprocess(
        """
        import dataclasses

        import jax

        from repro.configs import get_arch
        from repro.distributed.sharding import paged_cache_shardings
        from repro.launch.mesh import make_rollout_mesh
        from repro.models import model as M

        mesh = make_rollout_mesh(4)
        for arch in ("qwen2-1.5b", "hymba-1.5b"):
            cfg = dataclasses.replace(
                get_arch(arch).reduced(), n_heads=4, n_kv_heads=4,
                head_dim=16, d_model=64,
            )
            cache = M.init_paged_cache(cfg, 4, 64, 33, 8)
            placed = jax.device_put(
                cache, paged_cache_shardings(mesh, cache)
            )
            spec = placed["k"].sharding.spec
            assert spec[3] == "tensor", (arch, spec)
            shard = placed["k"].addressable_shards[0].data.shape
            assert shard[3] == cfg.n_kv_heads // 4, (arch, shard)
            assert placed["pos"].sharding.is_fully_replicated
            if "conv" in placed:
                assert placed["conv"].sharding.is_fully_replicated
                assert placed["ssm"].sharding.is_fully_replicated
        print("CACHE_OK")
        """,
        devices=8,
    )
    assert "CACHE_OK" in out


def test_rollout_params_shardings_placement_subprocess():
    """Shard-stored params: column dims split across the mesh (wq holds
    1/N of its output columns per device), reduction-side weights
    replicate."""
    out = _run_subprocess(
        """
        import dataclasses

        import jax

        from repro.configs import get_arch
        from repro.distributed.sharding import rollout_params_shardings
        from repro.launch.mesh import make_rollout_mesh
        from repro.models import model as M

        cfg = dataclasses.replace(
            get_arch("qwen2-1.5b").reduced(), n_heads=4, n_kv_heads=4,
            head_dim=16, d_model=64,
        )
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        mesh = make_rollout_mesh(4)
        placed = jax.device_put(
            params, rollout_params_shardings(mesh, params)
        )
        wq = placed["blocks"]["wq"]
        assert wq.sharding.spec[-1] == "tensor", wq.sharding.spec
        assert (
            wq.addressable_shards[0].data.shape[-1] == wq.shape[-1] // 4
        )
        assert placed["blocks"]["wo"].sharding.is_fully_replicated
        assert placed["embed"].sharding.is_fully_replicated
        print("PARAMS_OK")
        """,
        devices=8,
    )
    assert "PARAMS_OK" in out
