"""Architecture-zoo smoke tests: reduced config of every assigned arch runs
one forward/train step on CPU, asserts shapes + no NaNs, and checks
prefill+decode consistency against the training forward (the invariant the
rollout engine relies on for partial rollout / migration re-prefill)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, QWEN3_30B_A3B, get_arch
from repro.models import model

ALL = list(ASSIGNED) + [QWEN3_30B_A3B]
KEY = jax.random.PRNGKey(0)


def _inputs(cfg, b=2, s=32):
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1), (b, s), 0, cfg.vocab_size)
    fe = None
    if cfg.family == "vlm":
        fe = jax.random.normal(
            jax.random.fold_in(KEY, 2), (b, cfg.n_patches, cfg.d_model)
        ) * 0.02
    elif cfg.family == "audio":
        fe = jax.random.normal(
            jax.random.fold_in(KEY, 3), (b, cfg.encoder_seq, cfg.d_model)
        ) * 0.02
    return tokens, fe


@pytest.mark.parametrize("arch", [c.name for c in ALL])
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_arch(arch).reduced()
    params = model.init_params(cfg, KEY)
    tokens, fe = _inputs(cfg)
    logits, aux = model.forward(cfg, params, tokens, frontend_embeds=fe)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux["moe_aux"]))


@pytest.mark.parametrize("arch", [c.name for c in ALL])
def test_reduced_train_step_grads_finite(arch):
    cfg = get_arch(arch).reduced()
    params = model.init_params(cfg, KEY)
    tokens, fe = _inputs(cfg, b=1, s=16)

    def loss_fn(p):
        logits, aux = model.forward(cfg, p, tokens, frontend_embeds=fe)
        tgt = jnp.roll(tokens, -1, axis=1)
        ll = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(ll, tgt[..., None], axis=-1).mean()
        return nll + 0.01 * aux["moe_aux"]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)


@pytest.mark.parametrize("arch", [c.name for c in ALL])
def test_prefill_decode_matches_forward(arch):
    """prefill(prompt) + N decode steps must reproduce the training forward's
    next-token logits at every step (teacher forcing)."""
    cfg = get_arch(arch).reduced()
    if cfg.is_moe:
        # capacity drops depend on sequence length; a no-drop factor makes
        # prefill+decode exactly equivalent to the full forward
        import dataclasses

        cfg = dataclasses.replace(
            cfg, moe_capacity_factor=cfg.n_experts / cfg.top_k
        )
    params = model.init_params(cfg, KEY)
    b, prompt_len, total = 2, 8, 12
    tokens, fe = _inputs(cfg, b=b, s=total)

    # ground truth: full forward, logits at positions prompt_len-1 .. total-2
    full_logits, _ = model.forward(cfg, params, tokens, frontend_embeds=fe)

    # vlm caches must cover the prepended patch positions too
    extra = cfg.n_patches if cfg.family == "vlm" else 0
    cache = model.init_cache(cfg, b, max_len=total + 4 + extra)
    lengths = jnp.full((b,), prompt_len, jnp.int32)
    logits, cache = model.prefill(
        cfg, params, tokens[:, :prompt_len], lengths, cache, frontend_embeds=fe
    )
    np.testing.assert_allclose(
        logits, full_logits[:, prompt_len - 1], rtol=2e-4, atol=2e-4
    )
    for step in range(prompt_len, total - 1):
        logits, cache = model.decode_step(cfg, params, tokens[:, step], cache)
        np.testing.assert_allclose(
            logits, full_logits[:, step], rtol=2e-4, atol=2e-4,
            err_msg=f"{arch} decode step {step}",
        )


@pytest.mark.slow
def test_hybrid_ring_cache_long_decode():
    """hymba's windowed ring cache: decoding past the window stays finite and
    positions wrap."""
    cfg = get_arch("hymba-1.5b").reduced()
    assert cfg.sliding_window == 64
    params = model.init_params(cfg, KEY)
    b = 1
    # force ring mode: max_len beyond the long-context threshold
    cache = model.init_cache(cfg, b, max_len=cfg.long_context_threshold + 64)
    assert cache["k"].shape[2] == cfg.sliding_window
    tokens = jax.random.randint(KEY, (b, 16), 0, cfg.vocab_size)
    lengths = jnp.full((b,), 16, jnp.int32)
    logits, cache = model.prefill(cfg, params, tokens, lengths, cache)
    for i in range(80):  # well past the 64-wide window
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits, cache = model.decode_step(cfg, params, nxt, cache)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"][0]) == 96


def test_ssm_decode_constant_memory():
    """xLSTM decode cache has no sequence dimension at all."""
    cfg = get_arch("xlstm-1.3b").reduced()
    cache = model.init_cache(cfg, batch=2, max_len=1 << 19)
    leaves = jax.tree_util.tree_leaves(cache)
    total_floats = sum(l.size for l in leaves)
    assert total_floats < 1e6  # O(1) in max_len
    assert "k" not in cache


def test_moe_aux_loss_nonzero():
    cfg = get_arch("dbrx-132b").reduced()
    params = model.init_params(cfg, KEY)
    tokens, _ = _inputs(cfg)
    _, aux = model.forward(cfg, params, tokens)
    assert float(aux["moe_aux"]) > 0.0


def test_param_counts_full_configs_sane():
    """n_params estimates land in the right ballpark for known models."""
    q14 = get_arch("qwen2.5-14b")
    assert 12e9 < q14.n_params < 18e9
    x13 = get_arch("xlstm-1.3b")
    assert 0.7e9 < x13.n_params < 2.5e9
    q3 = get_arch("qwen3-30b-a3b")
    assert 24e9 < q3.n_params < 36e9
    assert 2e9 < q3.n_active_params < 5e9
