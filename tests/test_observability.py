"""Unified observability plane: metrics registry, lifecycle tracing,
Chrome trace export.

Covers:

* the registry primitives (counter/gauge/histogram, labels, the no-op
  fast path when disabled);
* ``obs.stats`` — the shared nearest-rank percentile convention the
  RewardServer and the benchmarks both migrated onto, plus the
  overwrite-oldest Ring;
* a traced cooperative (tick) run: span conservation, tracer-vs-manager
  staleness agreement, schema-valid export, and observability *off* by
  default;
* (slow) trace conservation under the threaded streaming stress with a
  mid-run replica failure and elastic scale-up — every ROUTED span must
  close with exactly one terminal event and realized staleness must
  match the protocol's accounting.
"""
import json
import threading
import time

import pytest

from repro.obs import (
    MetricsRegistry,
    NOOP_REGISTRY,
    Ring,
    TrajectoryTracer,
    export_chrome_trace,
    percentile,
    percentiles,
    validate_chrome_trace,
)


# ------------------------------------------------------------ metrics
def test_counter_gauge_labels():
    m = MetricsRegistry()
    m.counter("requests", inst=0).inc()
    m.counter("requests", inst=0).inc(2)
    m.counter("requests", inst=1).inc()
    assert m.counter("requests", inst=0).value == 3
    assert m.counter("requests", inst=1).value == 1
    g = m.gauge("depth")
    g.set(5)
    g.inc(-2)
    assert g.value == 3
    snap = m.snapshot()
    assert snap["requests{inst=0}"]["value"] == 3
    assert snap["depth"]["value"] == 3


def test_counter_set_total_is_monotone():
    m = MetricsRegistry()
    c = m.counter("scraped")
    c.set_total(10)
    c.set_total(7)  # a scrape racing an increment must not go backwards
    assert c.value == 10
    c.set_total(12)
    assert c.value == 12


def test_histogram_percentile_and_summary():
    m = MetricsRegistry()
    h = m.histogram("lat", buckets=(0.001, 0.01, 0.1, 1.0))
    for v in (0.0005, 0.005, 0.005, 0.05, 2.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 5
    assert s["max"] == 2.0
    # overflow percentile falls back to the observed max
    assert h.percentile(0.99) == 2.0
    # p50 lands in the 0.01 bucket (upper-bound estimate)
    assert h.percentile(0.5) == 0.01


def test_disabled_registry_is_noop():
    assert not NOOP_REGISTRY.enabled
    c = NOOP_REGISTRY.counter("x")
    c.inc(5)
    NOOP_REGISTRY.gauge("y").set(3)
    NOOP_REGISTRY.histogram("z").observe(1.0)
    assert NOOP_REGISTRY.snapshot() == {}
    # all instruments collapse to the same shared no-op object
    assert c is NOOP_REGISTRY.gauge("y")


# ------------------------------------------------------------ stats
def test_percentile_matches_repo_convention():
    # the nearest-rank rule every telemetry site used pre-unification:
    # sorted(samples)[min(len - 1, int(q * len))]
    samples = [5.0, 1.0, 3.0, 2.0, 4.0]
    s = sorted(samples)
    for q in (0.0, 0.5, 0.95, 0.99, 1.0):
        assert percentile(samples, q) == s[min(len(s) - 1, int(q * len(s)))]
    assert percentile([], 0.5) is None
    assert percentile([], 0.5, default=0.0) == 0.0
    assert percentiles([], (0.5, 0.99)) == {0.5: None, 0.99: None}


def test_ring_overwrites_oldest():
    r = Ring(4)
    for i in range(10):
        r.append(float(i))
    assert len(r) == 4
    assert r.total == 10
    assert sorted(r.values()) == [6.0, 7.0, 8.0, 9.0]


def test_reward_server_percentiles_unchanged():
    """The RewardServer's public percentile contract survived the
    migration onto obs.stats: same convention, None when empty."""
    from repro.core import (
        FnVerifier,
        RewardServer,
        TrajectoryLifecycle,
    )

    lifecycle = TrajectoryLifecycle()
    rs = RewardServer(FnVerifier(lambda p, r: 1.0), lifecycle)
    assert rs.latency_percentiles((0.5,)) == {0.5: None}
    rs._latencies.append(0.2)
    rs._latencies.append(0.1)
    assert rs.latency_percentiles((0.5, 0.99)) == {0.5: 0.2, 0.99: 0.2}


# ------------------------------------------------------- tracer units
def test_tracer_span_lifecycle_and_conservation():
    from repro.core import TrajectoryLifecycle
    from repro.core.types import Trajectory

    lifecycle = TrajectoryLifecycle()
    clock = {"t": 0.0}
    tracer = TrajectoryTracer(
        lifecycle, clock=lambda: clock["t"], floor_source=lambda: 3
    )
    t = Trajectory(traj_id=1, prompt=[1, 2], group_id=0)
    lifecycle.routed(t, 0, 1)
    clock["t"] = 1.0
    tracer.on_admit(0, [1])
    clock["t"] = 2.0
    lifecycle.completed(t, 0)
    clock["t"] = 2.5
    lifecycle.rewarded(t)
    clock["t"] = 3.0
    lifecycle.consumed(1)

    assert tracer.check_conservation() == []
    span = tracer.spans[1]
    assert span.terminal == "consumed"
    assert [s.kind for s in span.segments] == ["queue", "decode"]
    assert span.queue_wait() == 1.0
    assert span.decode_time() == 1.0
    # floor_source() - 1 - v_route = 3 - 1 - 1
    assert span.staleness == 1
    assert tracer.queue_lat.values() == [1.0]
    assert tracer.reward_lat.values() == [0.5]
    assert tracer.consume_lat.values() == [0.5]


def test_tracer_flags_double_terminal_and_unclosed():
    from repro.core import TrajectoryLifecycle
    from repro.core.types import Trajectory

    lifecycle = TrajectoryLifecycle()
    tracer = TrajectoryTracer(lifecycle)
    t = Trajectory(traj_id=7, prompt=[1], group_id=0)
    lifecycle.routed(t, 0, 0)
    problems = tracer.check_conservation()
    assert len(problems) == 1 and "never" in problems[0]
    assert tracer.check_conservation(allow_open=True) == []
    lifecycle.consumed(7)
    lifecycle.aborted(7)  # bug injection: second terminal
    problems = tracer.check_conservation()
    assert len(problems) == 1 and "2 terminal" in problems[0]


def test_tracer_migration_hops_and_preemption():
    from repro.core import TrajectoryLifecycle
    from repro.core.types import Trajectory

    lifecycle = TrajectoryLifecycle()
    tracer = TrajectoryTracer(lifecycle)
    t = Trajectory(traj_id=2, prompt=[1], group_id=0)
    lifecycle.routed(t, 0, 5)
    tracer.on_admit(0, [2])
    tracer.on_preempt(0, 2)
    lifecycle.interrupted(t)
    lifecycle.routed(t, 1, 4)  # migrated; late join lowers the version
    tracer.on_admit(1, [2])
    lifecycle.completed(t, 1)
    lifecycle.rewarded(t)
    lifecycle.consumed(2)
    span = tracer.spans[2]
    assert span.hops == 1
    assert span.preemptions == 1
    assert span.v_route == 4  # min over ROUTED versions
    assert span.instances == [0, 1]
    assert tracer.check_conservation() == []


def test_export_schema_and_validator():
    tracer = TrajectoryTracer()
    tracer.activity("work", 0.0, 1.0, track="t0", args={"n": 1})
    tracer.sample("fleet", {"active": 2.0}, ts=0.5)
    trace = export_chrome_trace(tracer)
    assert validate_chrome_trace(trace) == []
    phases = {e["ph"] for e in trace["traceEvents"]}
    assert {"M", "X", "C"} <= phases
    # validator catches structural damage
    bad = json.loads(json.dumps(trace))
    x_ev = next(e for e in bad["traceEvents"] if e["ph"] == "X")
    c_ev = next(e for e in bad["traceEvents"] if e["ph"] == "C")
    x_ev["ts"] = -1
    del c_ev["ph"]
    errs = validate_chrome_trace(bad)
    assert len(errs) == 2


# ------------------------------------------------- traced tick runtime
ARCH = None


def _mk_runtime(**kw):
    global ARCH
    from repro.configs import get_arch
    from repro.core.types import reset_traj_ids
    from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig

    if ARCH is None:
        ARCH = get_arch("qwen2-1.5b").reduced()
    reset_traj_ids()
    defaults = dict(
        eta=1, batch_size=2, group_size=2, n_instances=2, max_slots=2,
        max_len=48, max_new_tokens=8, total_steps=2, seed=0,
    )
    defaults.update(kw)
    return AsyncRLRuntime(ARCH, RuntimeConfig(**defaults))


def test_observability_off_by_default():
    rt = _mk_runtime()
    assert rt.tracer is None
    assert not rt.metrics.enabled
    # trace_path alone implies observability
    rt2 = _mk_runtime(trace_path="unused.json")
    assert rt2.tracer is not None


def test_traced_tick_run_reconstructs_staleness(tmp_path):
    path = str(tmp_path / "trace.json")
    rt = _mk_runtime(observability=True, trace_path=path)
    rt.run(max_ticks=3000)
    assert rt.model_version == 2
    assert rt.tracer.check_conservation(allow_open=True) == []
    # the trace's realized staleness is reconstructed from span versions
    # alone — it must agree with the protocol's own accounting
    assert (
        rt.tracer.realized_max_staleness()
        == rt.manager.max_consumed_staleness()
    )
    trace = json.loads(open(path).read())
    assert validate_chrome_trace(trace) == []
    other = trace["otherData"]
    assert other["conservation_violations"] == []
    assert other["spans"] > 0
    # engine hooks split queue vs decode: decode segments must exist
    assert any(
        e["name"] == "decode" and e["pid"] == 1
        for e in trace["traceEvents"]
    )
    # the registry mirrored the fleet counters on export
    assert rt.metrics.find("engine_decode_steps")
    assert rt.metrics.find("ps_pushes")


def test_traced_sim_run(tmp_path):
    from repro.sim.engine import SimConfig, StaleFlowSim

    path = str(tmp_path / "sim_trace.json")
    cfg = SimConfig(
        n_instances=2, batch_size=4, group_size=2, total_steps=2,
        observability=True, trace_path=path,
    )
    sim = StaleFlowSim(cfg)
    sim.run()
    assert sim.tracer.check_conservation(allow_open=True) == []
    assert (
        sim.tracer.realized_max_staleness()
        == sim.manager.max_consumed_staleness()
    )
    trace = json.loads(open(path).read())
    assert validate_chrome_trace(trace) == []


# --------------------------------------- threaded streaming conservation
@pytest.mark.slow
def test_trace_conservation_under_threaded_streaming_stress():
    """Trace conservation under the elastic streaming stress: with a
    replica failing and a new one joining mid-run, every ROUTED span must
    still close with exactly one terminal event, and the staleness the
    tracer reconstructs must match the manager and respect eta."""
    rt = _mk_runtime(
        scheduler="threaded", total_steps=3, n_instances=2, eta=2,
        streaming=True, stream_min_fill=1,
        stream_rebalance_interval_s=0.01,
        observability=True,
    )
    rt.scheduler.wall_timeout_s = 280.0
    runner = threading.Thread(target=rt.run, daemon=True)
    runner.start()
    deadline = time.perf_counter() + 120
    while time.perf_counter() < deadline:
        if rt.instances[1].decode_steps > 0 and rt.model_version >= 1:
            break
        time.sleep(0.05)
    assert rt.instances[1].decode_steps > 0, "instance 1 never decoded"

    rt.fail_instance(1)
    rt.manager.check_invariants()
    rt.add_instance(9)
    rt.manager.check_invariants()

    runner.join(timeout=280)
    assert not runner.is_alive(), "threaded streaming run did not finish"
    assert rt.model_version == 3

    # exactly one terminal per closed span, even across fail/add
    assert rt.tracer.check_conservation(allow_open=True) == []
    traced = rt.tracer.realized_max_staleness()
    assert traced == rt.manager.max_consumed_staleness()
    assert traced <= rt.rcfg.eta
    for s in rt.tracer.staleness_samples:
        assert 0 <= s <= rt.rcfg.eta
    # consumed spans outnumber steps*batch floor; export stays valid
    consumed = [
        s for s in rt.tracer.finished_spans() if s.terminal == "consumed"
    ]
    assert len(consumed) >= rt.rcfg.batch_size * rt.rcfg.group_size
    assert validate_chrome_trace(export_chrome_trace(rt.tracer)) == []
