"""ParameterServer concurrency regression tests.

The PS docstring has always promised database-style RW semantics (shared
Pulls, exclusive writer-preferred Push, background-Push overlap); with the
threaded scheduler those paths finally run under real concurrency, so the
promises get pinned here: stale pushes dropped under racing pushers,
concurrent Pulls sharing the read lock while a Push is pending behind
in-flight readers, writer preference never starving a Push, and FIFO
version ordering through the BackgroundPusher.
"""
import threading
import time

from repro.core import BackgroundPusher, ParameterServer


def test_stale_push_dropped_under_racing_pushers():
    ps = ParameterServer()
    versions = list(range(1, 33))
    import random

    rng = random.Random(0)
    rng.shuffle(versions)
    barrier = threading.Barrier(8)

    def pusher(chunk):
        barrier.wait()
        for v in chunk:
            ps.push({"v": v}, v)

    threads = [
        threading.Thread(target=pusher, args=(versions[i::8],))
        for i in range(8)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    params, version = ps.pull()
    assert version == 32
    assert params == {"v": 32}
    # monotonicity: whatever landed last, later pushes of older versions
    # were dropped, never published
    ps.push({"v": 5}, 5)
    assert ps.version == 32


def test_concurrent_pulls_share_the_read_lock():
    """Many Pulls must be in the critical section simultaneously (shared
    read), and a Push issued while they hold it waits for all of them."""
    ps = ParameterServer()
    ps.push({"v": 0}, 0)
    n = 6
    in_section = []
    max_concurrent = [0]
    gate = threading.Event()
    lock = threading.Lock()

    real_pull = ps.pull

    def slow_pull():
        with ps._rw.read():
            with lock:
                in_section.append(1)
                max_concurrent[0] = max(max_concurrent[0], len(in_section))
            gate.wait(timeout=5)
            with lock:
                in_section.pop()
            return ps._params, ps._version

    readers = [threading.Thread(target=slow_pull) for _ in range(n)]
    for t in readers:
        t.start()
    time.sleep(0.05)  # let every reader enter
    push_done = threading.Event()

    def pusher():
        ps.push({"v": 1}, 1)
        push_done.set()

    w = threading.Thread(target=pusher)
    w.start()
    time.sleep(0.05)
    # push is pending (writer waits for in-flight readers)...
    assert not push_done.is_set()
    # ...while every reader entered the section TOGETHER
    assert max_concurrent[0] == n
    gate.set()
    w.join(timeout=5)
    assert push_done.is_set()
    assert real_pull()[1] == 1
    for t in readers:
        t.join(timeout=5)


def test_writer_preference_never_starves_push():
    """A continuous stream of Pull traffic must not starve Push: once a
    writer waits, new readers queue behind it."""
    ps = ParameterServer()
    ps.push({"v": 0}, 0)
    stop = threading.Event()
    pulls = [0]

    def reader():
        while not stop.is_set():
            ps.pull()
            pulls[0] += 1

    readers = [threading.Thread(target=reader) for _ in range(4)]
    for t in readers:
        t.start()
    time.sleep(0.05)  # reader storm in flight
    t0 = time.perf_counter()
    ps.push({"v": 1}, 1)  # must acquire despite the storm
    elapsed = time.perf_counter() - t0
    stop.set()
    for t in readers:
        t.join(timeout=5)
    assert elapsed < 2.0, f"push starved for {elapsed:.2f}s"
    assert ps.version == 1
    assert pulls[0] > 0


def test_background_pusher_overlaps_and_keeps_fifo_order():
    ps = ParameterServer()
    ps.push({"v": 0}, 0)
    seen = []
    real_push = ps.push

    def recording_push(params, version):
        time.sleep(0.005)  # model DCN latency
        seen.append(version)
        real_push(params, version)

    ps.push = recording_push
    pusher = BackgroundPusher(ps).start()
    t0 = time.perf_counter()
    for v in range(1, 6):
        pusher.push({"v": v}, v)  # returns immediately: overlap is real
    submit_time = time.perf_counter() - t0
    pusher.flush()
    assert submit_time < 0.005 * 5, "push() blocked the trainer"
    assert seen == [1, 2, 3, 4, 5]  # FIFO: version order preserved
    assert ps.version == 5
    pusher.stop()


def test_background_pusher_synchronous_before_start():
    ps = ParameterServer()
    pusher = BackgroundPusher(ps)  # never started
    pusher.push({"v": 3}, 3)
    assert ps.version == 3
