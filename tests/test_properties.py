"""Cross-cutting property tests (hypothesis) for system invariants not
covered by the per-module suites."""
import random

from _optional import given, settings, st

from repro.core.cost_model import CostModel, fit_coefficients
from repro.core.parameter_server import plan_transfers
from repro.core.snapshot import InstanceSnapshot
from repro.core.trajectory_server import TrajectoryServer
from repro.core.types import reset_traj_ids


# ------------------------------------------------------------ comm planner
@settings(max_examples=50, deadline=None)
@given(
    n_slices=st.integers(1, 40),
    n_senders=st.integers(1, 6),
    seed=st.integers(0, 10_000),
)
def test_plan_transfers_near_optimal_makespan(n_slices, n_senders, seed):
    """Greedy LPT balancing: makespan <= 2x the trivial lower bound
    (classic multiprocessor-scheduling guarantee)."""
    rng = random.Random(seed)
    senders = [f"s{i}" for i in range(n_senders)]
    required = [
        (f"x{i}", rng.randint(1, 10_000), "r", senders) for i in range(n_slices)
    ]
    bw = 100.0
    plan = plan_transfers(required, lambda s, r: bw, fixed_latency=0.0)
    total = sum(n for _, n, _, _ in required) / bw
    lower = max(total / n_senders, max(n for _, n, _, _ in required) / bw)
    assert plan.makespan <= 2.0 * lower + 1e-9
    # every slice assigned exactly once
    assert len(plan.transfers) == n_slices


# -------------------------------------------------------------- cost model
@settings(max_examples=50, deadline=None)
@given(
    k1=st.floats(1e-15, 1e-9),
    k2=st.floats(1e-5, 1e-2),
    k3=st.floats(1e-6, 1e-3),
    k4=st.floats(1e-4, 1e-1),
    n=st.integers(1, 200),
    kv=st.floats(0, 1e9),
)
def test_cost_model_basic_properties(k1, k2, k3, k4, n, kv):
    cm = CostModel(k1=k1, k2=k2, k3=k3, k4=k4, k5=1000.0, kv_budget=1e12)
    s = InstanceSnapshot(0, kv_cache=kv, run_trajs=set(range(n)))
    t = cm.throughput(s)
    assert t >= 0
    # throughput saturates below the compute-bound ceiling 1/k3
    assert t <= 1.0 / k3 + 1e-9
    # marginal gain of an admissible route is bounded by the idle ceiling
    # ONLY when the instance is already slower than idle; in all cases the
    # post-route state must remain consistent:
    s2 = cm.with_routed(s, 999, 100)
    assert 999 in s2.run_trajs or 999 in s2.wait_trajs


def test_fit_coefficients_recovers_known_model():
    true = CostModel(k1=2e-10, k2=3e-3, k3=2e-4, k4=8e-3, k5=1000.0,
                     kv_budget=1e12)
    samples = []
    for n in (1, 2, 4, 8, 16, 32, 64):
        for kv in (0.0, 1e6, 1e7, 1e8):
            samples.append((kv, n, true.step_latency(kv, n)))
    fit = fit_coefficients(samples, k5=1000.0, kv_budget=1e12)
    for kv, n, lat in samples:
        pred = fit.step_latency(kv, n)
        assert abs(pred - lat) / lat < 0.05


# ------------------------------------------------------------------- TS
@settings(max_examples=40, deadline=None)
@given(
    capacity=st.integers(1, 6),
    group_size=st.integers(1, 3),
    n_ops=st.integers(1, 60),
    seed=st.integers(0, 2**31),
)
def test_trajectory_server_capacity_invariant(capacity, group_size, n_ops, seed):
    """Live groups never exceed capacity; registry/queue stay consistent
    under random take/put_back/complete/drop/retire/refill sequences."""
    reset_traj_ids()
    rng = random.Random(seed)
    src = iter([[1, 2, 3]] * 10_000)
    ts = TrajectoryServer(src, capacity_groups=capacity, group_size=group_size)
    ts.refill()
    taken = []
    for _ in range(n_ops):
        op = rng.choice(["take", "back", "complete", "drop", "retire", "refill"])
        if op == "take" and ts.n_available:
            t = rng.choice(ts.peek())
            ts.take(t.traj_id)
            taken.append(t.traj_id)
        elif op == "back" and taken:
            ts.put_back(taken.pop(rng.randrange(len(taken))))
        elif op == "complete" and taken:
            ts.complete(taken.pop(rng.randrange(len(taken))))
        elif op == "drop" and taken:
            ts.drop(taken.pop(rng.randrange(len(taken))))
        elif op == "retire":
            done = [tid for tid in ts.registry
                    if ts.registry[tid].status.value == "generated"]
            if done:
                ts.retire(done[0])
        elif op == "refill":
            ts.refill()
        assert ts._live_groups <= capacity
        assert len(ts.groups) == ts._live_groups
        # available is always a subset of the registry
        for t in ts.peek():
            assert t.traj_id in ts.registry
