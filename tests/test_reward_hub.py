"""Reward hub: remote/sandboxed verifiers with timeouts, retries & fault
injection.

Four layers, bottom up:

* retry machinery — backoff shape, bounded attempts, circuit-breaker
  state machine (injectable clock, no sleeping);
* verifier clients — HTTP submit-then-poll against the hermetic
  loopback :class:`StubJudge`, and the resource-limited subprocess
  sandbox (kill-on-timeout);
* the hub + RewardServer failure contract — every completion reaches
  exactly one disposition (REWARDED, clean ABORTED, counted drop), no
  worker thread dies, backpressure is real (satellites 3 & 4);
* runtime acceptance — the threaded scheduler under seeded fault
  injection: tracer span conservation, staleness <= eta, full worker
  pool alive, and the faults demonstrably fired (the tentpole's
  provability gate).

Everything is hermetic: loopback HTTP + local subprocesses only.
"""
import time

import pytest

from repro.core import (
    FnVerifier,
    RewardServer,
    RewardServerConfig,
    TrajectoryLifecycle,
)
from repro.core.lifecycle import LifecycleEventKind
from repro.core.types import Trajectory, next_traj_id, reset_traj_ids
from repro.reward import (
    BreakerState,
    CircuitBreaker,
    Fault,
    FaultInjectingVerifier,
    FaultSchedule,
    HttpVerifier,
    InjectedCrash,
    RetryPolicy,
    RetryingVerifier,
    RewardHub,
    SandboxVerifier,
    StubJudge,
    VerificationAbort,
    VerifierError,
    VerifierTimeout,
    run_with_retries,
)

FAST = RetryPolicy(
    max_attempts=3, request_timeout_s=2.0,
    backoff_base_s=0.001, backoff_cap_s=0.01,
)


def mk_traj(task="", prompt=None, response=None, group_id=-1):
    t = Trajectory(
        traj_id=next_traj_id(), prompt=prompt or [1, 2],
        group_id=group_id, task=task,
    )
    t.response = response or [3, 4]
    return t


# =========================================================== retry machinery
class TestRetryPolicy:
    def test_backoff_grows_exponentially_to_cap(self):
        import random

        p = RetryPolicy(backoff_base_s=0.1, backoff_cap_s=0.5, jitter=0.0)
        rng = random.Random(0)
        waits = [p.backoff(k, rng) for k in range(5)]
        assert waits == [0.1, 0.2, 0.4, 0.5, 0.5]

    def test_jitter_bounded_multiplicative(self):
        import random

        p = RetryPolicy(backoff_base_s=0.1, backoff_cap_s=10.0, jitter=0.5)
        rng = random.Random(7)
        for k in range(4):
            w = p.backoff(k, rng)
            base = 0.1 * 2 ** k
            assert base <= w < base * 1.5

    def test_succeeds_after_transient_failures(self):
        calls = {"n": 0}
        slept = []

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise VerifierError("transient")
            return 42.0

        retried = []
        out = run_with_retries(
            flaky, FAST, sleep=slept.append,
            on_retry=lambda a, e: retried.append(a),
        )
        assert out == 42.0
        assert calls["n"] == 3
        assert len(slept) == 2 and retried == [0, 1]

    def test_exhaustion_raises_with_cause(self):
        def dead():
            raise VerifierError("always")

        with pytest.raises(VerifierError) as ei:
            run_with_retries(dead, FAST, sleep=lambda s: None)
        assert "3 attempts" in str(ei.value)
        assert isinstance(ei.value.__cause__, VerifierError)

    def test_verification_abort_passes_through_untried(self):
        calls = {"n": 0}

        def aborting():
            calls["n"] += 1
            raise VerificationAbort("code", 7)

        with pytest.raises(VerificationAbort):
            run_with_retries(aborting, FAST, sleep=lambda s: None)
        assert calls["n"] == 1  # terminal decision: never retried


class TestCircuitBreaker:
    def test_opens_after_threshold_then_half_open_probe(self):
        clock = {"t": 0.0}
        b = CircuitBreaker(
            failure_threshold=3, reset_timeout_s=10.0,
            clock=lambda: clock["t"],
        )
        for _ in range(3):
            assert b.allow()
            b.record_failure()
        assert b.state is BreakerState.OPEN
        assert not b.allow() and b.fast_failures == 1

        clock["t"] = 11.0  # past the reset timeout: half-open
        assert b.allow()
        assert b.state is BreakerState.HALF_OPEN
        assert not b.allow()  # single probe at a time
        b.record_success()
        assert b.state is BreakerState.CLOSED
        assert b.allow()

    def test_half_open_probe_failure_reopens(self):
        clock = {"t": 0.0}
        b = CircuitBreaker(2, 5.0, clock=lambda: clock["t"])
        b.record_failure(), b.record_failure()
        assert b.state is BreakerState.OPEN
        clock["t"] = 6.0
        assert b.allow()
        b.record_failure()  # probe failed
        assert b.state is BreakerState.OPEN
        assert not b.allow()  # re-opened with a fresh timeout
        assert b.opened == 2

    def test_open_breaker_fails_fast_in_retry_loop(self):
        b = CircuitBreaker(1, 1000.0)
        b.record_failure()
        calls = {"n": 0}

        def fn():
            calls["n"] += 1
            return 1.0

        from repro.reward import VerifierUnavailable

        with pytest.raises(VerifierUnavailable):
            run_with_retries(fn, FAST, breaker=b, sleep=lambda s: None)
        assert calls["n"] == 0  # backend never touched


class TestRetryingVerifier:
    def test_absorbs_transients_and_counts(self):
        calls = {"n": 0}

        def fn(p, r):
            calls["n"] += 1
            if calls["n"] % 2 == 1:
                raise ValueError("flaky")
            return 1.0

        v = RetryingVerifier(FnVerifier(fn), FAST, sleep=lambda s: None)
        assert v.score([1], [2]) == 1.0
        assert v.score([1], [2]) == 1.0
        s = v.stats()
        assert s["calls"] == 2 and s["retries"] == 2 and s["exhausted"] == 0

    def test_exhaustion_counted_and_raised(self):
        def fn(p, r):
            raise ValueError("dead verifier")

        v = RetryingVerifier(FnVerifier(fn), FAST, sleep=lambda s: None)
        with pytest.raises(VerifierError):
            v.score([1], [2])
        assert v.stats()["exhausted"] == 1


# ====================================================== HTTP verifier client
class TestHttpVerifier:
    def test_submit_then_poll_happy_path(self):
        with StubJudge(score_fn=lambda p, r, task: float(sum(r)),
                       pending_polls=2) as judge:
            v = HttpVerifier(judge.url, policy=FAST, total_timeout_s=10.0,
                             poll_interval_s=0.001)
            assert v.score([1], [2, 3]) == 5.0
        # 1 submit + 2 pending polls + 1 done poll
        assert judge.submits == 1 and judge.polls == 3
        assert v.requests == 4 and v.retries == 0

    def test_inline_judge_short_circuits_polling(self):
        with StubJudge(inline=True) as judge:
            v = HttpVerifier(judge.url, policy=FAST)
            assert v.score([1], [2]) == 1.0
        assert judge.polls == 0

    def test_retries_through_injected_500s(self):
        with StubJudge(fail_first=2, inline=True) as judge:
            v = HttpVerifier(judge.url, policy=FAST)
            assert v.score([1], [2]) == 1.0
        assert v.retries == 2 and judge.errors_served == 2

    def test_end_to_end_deadline_raises_timeout(self):
        with StubJudge(pending_polls=10_000) as judge:
            v = HttpVerifier(judge.url, policy=FAST, total_timeout_s=0.05,
                             poll_interval_s=0.001)
            with pytest.raises(VerifierTimeout):
                v.score([1], [2])
        assert v.timeouts == 1 and v.failures == 1

    def test_unreachable_judge_exhausts_attempts(self):
        judge = StubJudge()  # bound but never started, then closed:
        url = judge.url      # connection refused on every request
        judge._server.server_close()
        v = HttpVerifier(
            url,
            policy=RetryPolicy(max_attempts=2, request_timeout_s=0.2,
                               backoff_base_s=0.001, backoff_cap_s=0.005),
        )
        with pytest.raises(VerifierError):
            v.score([1], [2])
        assert v.requests == 2 and v.failures == 1

    def test_score_trajectory_carries_task_tag(self):
        seen = {}

        def score_fn(p, r, task):
            seen["task"] = task
            return 1.0

        with StubJudge(score_fn=score_fn, inline=True) as judge:
            v = HttpVerifier(judge.url, policy=FAST)
            v.score_trajectory(mk_traj(task="code"))
        assert seen["task"] == "code"


# =========================================================== sandbox verifier
class TestSandboxVerifier:
    def test_scores_inline_program(self):
        v = SandboxVerifier(
            "def score(p, r):\n    return float(len(p) + len(r))",
            timeout_s=10.0,
        )
        assert v.score([1, 2], [3]) == 3.0
        assert v.stats()["calls"] == 1 and v.stats()["failures"] == 0

    def test_from_spec_reads_program_file(self, tmp_path):
        prog = tmp_path / "scorer.py"
        prog.write_text("def score(p, r):\n    return 0.25")
        v = SandboxVerifier.from_spec(f"@{prog}", timeout_s=10.0)
        assert v.score([1], [2]) == 0.25

    def test_stdout_noise_before_score_line_is_tolerated(self):
        v = SandboxVerifier(
            "print('debug noise')\n"
            "def score(p, r):\n"
            "    print('more noise')\n"
            "    return 1.0",
            timeout_s=10.0,
        )
        assert v.score([1], [2]) == 1.0

    def test_hung_program_is_killed_at_wall_deadline(self):
        v = SandboxVerifier(
            "import time\n"
            "def score(p, r):\n"
            "    time.sleep(3600)",
            timeout_s=0.5,
        )
        t0 = time.perf_counter()
        with pytest.raises(VerifierTimeout):
            v.score([1], [2])
        assert time.perf_counter() - t0 < 10.0  # killed, not waited out
        assert v.kills == 1 and v.failures == 1

    def test_program_without_score_fn_is_an_error(self):
        v = SandboxVerifier("x = 1", timeout_s=10.0)
        with pytest.raises(VerifierError):
            v.score([1], [2])
        assert v.failures == 1

    def test_crashing_program_is_an_error_not_a_hang(self):
        v = SandboxVerifier(
            "def score(p, r):\n    raise RuntimeError('boom')",
            timeout_s=10.0,
        )
        with pytest.raises(VerifierError) as ei:
            v.score([1], [2])
        assert "boom" in str(ei.value)

    def test_environment_is_scrubbed(self):
        import os

        os.environ["REWARD_HUB_SECRET_CANARY"] = "leak"
        try:
            v = SandboxVerifier(
                "import os\n"
                "def score(p, r):\n"
                "    return 1.0 if 'REWARD_HUB_SECRET_CANARY' in os.environ"
                " else 0.0",
                timeout_s=10.0,
            )
            assert v.score([1], [2]) == 0.0
        finally:
            del os.environ["REWARD_HUB_SECRET_CANARY"]


# ============================================================ fault injection
class TestFaultSchedule:
    def test_explicit_sequence_then_ok(self):
        s = FaultSchedule(["error", "drop", "ok"])
        assert [s.at(i).kind for i in range(5)] == \
            ["error", "drop", "ok", "ok", "ok"]

    def test_explicit_cycle(self):
        s = FaultSchedule(["ok", "crash"], cycle=True)
        assert [s.at(i).kind for i in range(4)] == \
            ["ok", "crash", "ok", "crash"]

    def test_seeded_rates_are_order_independent(self):
        a = FaultSchedule(seed=9, error_rate=0.3, drop_rate=0.2)
        b = FaultSchedule(seed=9, error_rate=0.3, drop_rate=0.2)
        idx = list(range(200))
        import random as _r

        _r.Random(1).shuffle(idx)
        got_a = {i: a.at(i).kind for i in range(200)}
        got_b = {i: b.at(i).kind for i in idx}  # different visit order
        assert got_a == got_b
        kinds = set(got_a.values())
        assert "error" in kinds and "ok" in kinds  # rates actually draw

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            Fault("meltdown")


class TestFaultInjectingVerifier:
    def test_each_kind_maps_to_its_exception(self):
        inner = FnVerifier(lambda p, r: 1.0)
        v = FaultInjectingVerifier(
            inner,
            FaultSchedule(["ok", "error", "crash", "drop", Fault("delay",
                                                                 0.001)]),
            drop_hang_s=0.0, sleep=lambda s: None,
        )
        assert v.score([1], [2]) == 1.0
        with pytest.raises(VerifierError):
            v.score([1], [2])
        with pytest.raises(InjectedCrash):
            v.score([1], [2])
        with pytest.raises(VerifierTimeout):
            v.score([1], [2])
        assert v.score([1], [2]) == 1.0  # delay then pass through
        assert v.counts == {"ok": 1, "error": 1, "crash": 1, "drop": 1,
                            "delay": 1}
        assert v.injected() == 4


# ==================================================================== the hub
class TestRewardHub:
    def test_routes_by_task_tag_with_default(self):
        hub = RewardHub(default=FnVerifier(lambda p, r: 0.0))
        hub.register("math", FnVerifier(lambda p, r: 1.0))
        hub.register("code", FnVerifier(lambda p, r: 2.0))
        assert hub.score_trajectory(mk_traj(task="math")) == 1.0
        assert hub.score_trajectory(mk_traj(task="code")) == 2.0
        assert hub.score_trajectory(mk_traj(task="prose")) == 0.0  # default
        assert hub.score([1], [2]) == 0.0  # bare protocol -> default
        routes = hub.stats()["routes"]
        assert routes["math"]["calls"] == 1
        assert routes["default"]["calls"] == 2

    def test_unrouted_without_default_resolves_to_fallback(self):
        hub = RewardHub(on_failure="fallback", fallback_score=-3.0)
        hub.register("math", FnVerifier(lambda p, r: 1.0))
        assert hub.score_trajectory(mk_traj(task="unknown")) == -3.0
        assert hub.stats()["unrouted"] == 1

    def test_verifier_failure_resolves_to_fallback_score(self):
        def boom(p, r):
            raise RuntimeError("verifier down")

        hub = RewardHub(default=FnVerifier(boom), fallback_score=0.5)
        assert hub.score_trajectory(mk_traj()) == 0.5
        route = hub.stats()["routes"]["default"]
        assert route["failures"] == 1 and route["fallbacks"] == 1

    def test_abort_mode_raises_verification_abort_with_context(self):
        def boom(p, r):
            raise RuntimeError("verifier down")

        hub = RewardHub(on_failure="abort")
        hub.register("code", FnVerifier(boom))
        t = mk_traj(task="code")
        with pytest.raises(VerificationAbort) as ei:
            hub.score_trajectory(t)
        assert ei.value.tag == "code" and ei.value.traj_id == t.traj_id
        assert isinstance(ei.value.cause, RuntimeError)
        assert hub.stats()["routes"]["code"]["aborts"] == 1

    def test_invalid_failure_policy_rejected(self):
        with pytest.raises(ValueError):
            RewardHub(on_failure="shrug")

    def test_per_route_metrics_labeled(self):
        from repro.obs import MetricsRegistry

        m = MetricsRegistry()
        hub = RewardHub(default=FnVerifier(lambda p, r: 1.0), metrics=m)
        hub.register("math", FnVerifier(lambda p, r: 1.0))
        hub.score_trajectory(mk_traj(task="math"))
        hub.score_trajectory(mk_traj())
        snap = m.snapshot()
        names = set(snap)
        assert any("reward_hub_scores" in n and "math" in n for n in names)
        assert any("reward_hub_scores" in n and "default" in n
                   for n in names)


# ======================================= RewardServer failure contract (sat 3)
class TestRewardServerFailureContract:
    def _server(self, verifier, **cfg_kw):
        lifecycle = TrajectoryLifecycle()
        server = RewardServer(
            verifier, lifecycle, RewardServerConfig(**cfg_kw)
        )
        return lifecycle, server

    def test_worker_survives_verifier_crash(self):
        """Regression (satellite 3): an exception escaping the verifier
        in a threaded worker used to kill the thread silently — the pool
        shrank for the rest of the run. Now it scores 0.0 and lives."""
        crash_then_ok = FaultInjectingVerifier(
            FnVerifier(lambda p, r: 1.0),
            FaultSchedule(["crash", "crash", "ok", "ok"]),
        )
        lifecycle, server = self._server(crash_then_ok, n_workers=2)
        server.start()
        for _ in range(4):
            lifecycle.completed(mk_traj())
        assert server.drain(timeout=30.0)
        assert server.alive_workers() == 2  # nobody died
        assert server.scored == 4  # crashes scored 0.0, not lost
        assert server.errors == 2
        server.stop()

    def test_rewarded_subscriber_crash_counted_not_fatal(self):
        lifecycle, server = self._server(
            FnVerifier(lambda p, r: 1.0), n_workers=1
        )

        def bad_subscriber(e):
            raise RuntimeError("downstream bug")

        lifecycle.subscribe(LifecycleEventKind.REWARDED, bad_subscriber)
        server.start()
        for _ in range(3):
            lifecycle.completed(mk_traj())
        assert server.drain(timeout=30.0)
        assert server.alive_workers() == 1
        assert server.worker_errors == 3
        server.stop()

    def test_verification_abort_publishes_aborted_not_rewarded(self):
        hub = RewardHub(on_failure="abort")
        hub.register("", FaultInjectingVerifier(
            FnVerifier(lambda p, r: 1.0),
            FaultSchedule(["error", "ok"]),
        ))
        seen = {"rewarded": [], "aborted": []}
        lifecycle = TrajectoryLifecycle()
        lifecycle.subscribe(
            LifecycleEventKind.REWARDED,
            lambda e: seen["rewarded"].append(e.traj_id),
        )
        lifecycle.subscribe(
            LifecycleEventKind.ABORTED,
            lambda e: seen["aborted"].append(e.traj_id),
        )
        server = RewardServer(hub, lifecycle, RewardServerConfig())
        t_bad, t_ok = mk_traj(), mk_traj()
        lifecycle.completed(t_bad)   # inner raises -> hub aborts
        lifecycle.completed(t_ok)
        assert seen["aborted"] == [t_bad.traj_id]
        assert seen["rewarded"] == [t_ok.traj_id]
        assert server.aborted == 1 and server.scored == 1
        assert server.drain(timeout=1.0)  # dispositions add up

    def test_on_abort_hook_receives_the_trajectory(self):
        hub = RewardHub(on_failure="abort")
        hub.register("", FnVerifier(
            lambda p, r: (_ for _ in ()).throw(RuntimeError("down"))
        ))
        got = []
        lifecycle = TrajectoryLifecycle()
        server = RewardServer(
            hub, lifecycle, RewardServerConfig(), on_abort=got.append
        )
        t = mk_traj()
        lifecycle.completed(t)
        assert got == [t]
        assert server.aborted == 1

    def test_raising_liveness_probe_drops_and_counts(self):
        lifecycle = TrajectoryLifecycle()
        server = RewardServer(
            FnVerifier(lambda p, r: 1.0), lifecycle, RewardServerConfig(),
            liveness=lambda t: (_ for _ in ()).throw(KeyError("probe bug")),
        )
        lifecycle.completed(mk_traj())
        assert server.dropped == 1 and server.worker_errors == 1
        assert server.scored == 0

    def test_worker_error_metric_mirrors_counter(self):
        from repro.obs import MetricsRegistry

        m = MetricsRegistry()
        lifecycle = TrajectoryLifecycle()
        server = RewardServer(
            FnVerifier(lambda p, r: 1.0), lifecycle, RewardServerConfig(),
            metrics=m,
        )
        lifecycle.subscribe(
            LifecycleEventKind.REWARDED,
            lambda e: (_ for _ in ()).throw(RuntimeError("bug")),
        )
        lifecycle.completed(mk_traj())
        assert server.worker_errors == 1
        snap = m.snapshot()
        (name,) = [n for n in snap if "reward_worker_errors" in n]
        assert snap[name]["value"] == 1


# ====================================== RewardServer backpressure (satellite 4)
class TestRewardServerBackpressure:
    def test_full_queue_blocks_submitter_and_drains(self):
        """Bounded queue + slow verifier: the submitting thread observably
        back-pressures (the paper's rollout-cannot-outrun-verification
        property), then everything drains and latency percentiles are
        sane."""
        lifecycle = TrajectoryLifecycle()
        server = RewardServer(
            FnVerifier(lambda p, r: 1.0), lifecycle,
            RewardServerConfig(
                n_workers=1, queue_capacity=2, simulated_latency=0.02
            ),
        )
        server.start()
        n = 10
        t0 = time.perf_counter()
        for _ in range(n):
            lifecycle.completed(mk_traj())  # blocks when the queue is full
        submit_wall = time.perf_counter() - t0
        # 10 submissions through a capacity-2 queue behind one 20ms-per-
        # score worker: the submitter must have waited for most of the
        # scoring time, not returned instantly
        assert submit_wall > 0.02 * (n - 4), \
            f"no backpressure: {n} submits took {submit_wall:.3f}s"
        assert server.queue_depth() <= 2

        assert server.drain(timeout=30.0)
        server.stop()
        assert server.scored == n and server.dropped == 0
        pct = server.latency_percentiles((0.5, 0.95))
        assert pct[0.5] is not None and pct[0.95] is not None
        assert 0.0 < pct[0.5] <= pct[0.95]

    def test_liveness_gate_drops_dead_work_while_queued(self):
        alive = set()
        lifecycle = TrajectoryLifecycle()
        server = RewardServer(
            FnVerifier(lambda p, r: 1.0), lifecycle,
            RewardServerConfig(n_workers=1),
            liveness=lambda t: t.traj_id in alive,
        )
        t_live, t_dead = mk_traj(), mk_traj()
        alive.add(t_live.traj_id)
        server.start()
        lifecycle.completed(t_live)
        lifecycle.completed(t_dead)  # aborted while queued: never scored
        assert server.drain(timeout=10.0)
        server.stop()
        assert server.scored == 1 and server.dropped == 1

    def test_stop_without_drain_drops_queued_work(self):
        lifecycle = TrajectoryLifecycle()
        server = RewardServer(
            FnVerifier(lambda p, r: 1.0), lifecycle,
            RewardServerConfig(n_workers=1, simulated_latency=0.05),
        )
        server.start()
        for _ in range(4):
            lifecycle.completed(mk_traj())
        server.stop(drain=False)
        stats = server.stats()
        assert stats["scored"] + stats["dropped"] == stats["submitted"]
        # post-stop completions are dropped, not scored into torn-down state
        lifecycle.completed(mk_traj())
        assert server.stats()["dropped"] >= 1


# ================================================= runtime integration (slow)
@pytest.fixture
def runtime_factory():
    from repro.configs import get_arch
    from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig

    arch = get_arch("qwen2-1.5b").reduced()

    def mk(**kw):
        reset_traj_ids()
        defaults = dict(
            eta=1, batch_size=2, group_size=2, n_instances=2, max_slots=2,
            max_len=48, max_new_tokens=8, total_steps=2, seed=0,
        )
        defaults.update(kw)
        return AsyncRLRuntime(arch, RuntimeConfig(**defaults))

    return mk


class TestRuntimeIntegration:
    def test_score_url_builds_hub_and_scrapes_route_metrics(
        self, runtime_factory
    ):
        with StubJudge(inline=True) as judge:
            rt = runtime_factory(score_url=judge.url, observability=True)
            assert rt.reward_hub is not None
            assert set(rt.reward_hub.tags()) >= {"", "math", "remote"}
            rt.run(max_ticks=3000)
            assert rt.model_version == 2
            assert judge.submits > 0  # completions really crossed HTTP
            rt.scrape_metrics()
        names = set(rt.metrics.snapshot())
        assert any("reward_route_calls" in n for n in names)
        assert any("reward_route_breaker_open" in n for n in names)

    def test_score_sandbox_routes_code_tag(self, runtime_factory):
        rt = runtime_factory(
            score_sandbox="def score(p, r):\n    return 1.0",
        )
        assert rt.reward_hub is not None
        assert "code" in rt.reward_hub.tags()
        # default route stays the in-process RewardModel (no score_url)
        route = rt.reward_hub.route_for("anything-else")
        assert type(route.verifier).__name__ == "RewardModel"

    def test_explicit_verifier_override_wins(self, runtime_factory):
        flat = FnVerifier(lambda p, r: 1.0)
        rt = runtime_factory(verifier=flat)
        assert rt.reward_server.verifier is flat
        rt.run(max_ticks=3000)
        assert rt.model_version == 2
        h = rt.history
        assert all(rec.mean_reward == 1.0 for rec in h)

    def test_tick_abort_mode_releases_groups(self, runtime_factory):
        """Cooperative scheduler + hub in abort mode: an unverifiable
        trajectory aborts its whole group, the protocol entry is released
        (no stuck Reserved entry), and training still completes on the
        surviving groups."""
        faulty = FaultInjectingVerifier(
            FnVerifier(lambda p, r: 1.0),
            FaultSchedule(seed=5, error_rate=0.15),
        )
        hub = RewardHub(default=faulty, on_failure="abort")
        rt = runtime_factory(verifier=hub, total_steps=2)
        rt.run(max_ticks=20000)
        assert rt.model_version == 2
        assert rt.reward_server.aborted > 0, \
            "no aborts fired: the test proved nothing"
        rt.manager.check_invariants()
        assert rt.manager.max_consumed_staleness() <= rt.rcfg.eta


class TestThreadedFaultAcceptance:
    """The tentpole's acceptance gate: seeded fault injection under the
    threaded scheduler with staleness <= eta."""

    def test_threaded_fallback_under_faults(
        self, runtime_factory, lock_witnessed
    ):
        faulty = FaultInjectingVerifier(
            FnVerifier(lambda p, r: 1.0),
            FaultSchedule(seed=11, error_rate=0.15, crash_rate=0.1,
                          delay_rate=0.2, delay_s=0.002),
        )
        hub = RewardHub(default=faulty, on_failure="fallback",
                        fallback_score=0.0)
        rt = runtime_factory(
            verifier=hub, scheduler="threaded", total_steps=2,
            observability=True, reward_workers=2,
        )
        rt.scheduler.wall_timeout_s = 240.0
        # sample the pool from inside REWARDED dispatch (worker threads):
        # a silently-died sibling would show up as a shrunken count
        alive = []
        rt.lifecycle.subscribe(
            LifecycleEventKind.REWARDED,
            lambda e: alive.append(rt.reward_server.alive_workers()),
        )
        rt.run()
        assert rt.model_version == 2
        # every ROUTED span closed with exactly one terminal event
        violations = rt.tracer.check_conservation(allow_open=True)
        assert violations == [], violations
        # staleness bound held on everything consumed
        assert rt.manager.max_consumed_staleness() <= rt.rcfg.eta
        assert rt.tracer.realized_max_staleness() <= rt.rcfg.eta
        rt.manager.check_invariants()
        # the worker pool survived every injected crash
        assert alive and min(alive) == rt.rcfg.reward_workers
        stats = rt.reward_server.stats()
        assert stats["scored"] + stats["dropped"] + stats["aborted"] \
            == stats["submitted"]
        # and the faults demonstrably fired
        assert faulty.injected() > 0

    @pytest.mark.slow
    def test_threaded_abort_mode_under_faults(
        self, runtime_factory, lock_witnessed
    ):
        faulty = FaultInjectingVerifier(
            FnVerifier(lambda p, r: 1.0),
            FaultSchedule(seed=3, error_rate=0.3),
        )
        hub = RewardHub(default=faulty, on_failure="abort")
        rt = runtime_factory(
            verifier=hub, scheduler="threaded", total_steps=2, eta=2,
            observability=True,
        )
        rt.scheduler.wall_timeout_s = 240.0
        alive = []
        rt.lifecycle.subscribe(
            LifecycleEventKind.REWARDED,
            lambda e: alive.append(rt.reward_server.alive_workers()),
        )
        rt.run()
        assert rt.model_version == 2
        violations = rt.tracer.check_conservation(allow_open=True)
        assert violations == [], violations
        assert rt.manager.max_consumed_staleness() <= rt.rcfg.eta
        rt.manager.check_invariants()
        assert alive and min(alive) == rt.rcfg.reward_workers
        stats = rt.reward_server.stats()
        assert stats["scored"] + stats["dropped"] + stats["aborted"] \
            == stats["submitted"]
        assert faulty.injected() > 0
        assert stats["aborted"] > 0  # the abort path actually ran

    @pytest.mark.slow
    def test_threaded_remote_judge_end_to_end(
        self, runtime_factory, lock_witnessed
    ):
        """Completions cross real loopback HTTP from reward workers while
        instances decode: the disaggregated reward phase with an external
        judge, end to end."""
        with StubJudge(score_fn=lambda p, r, task: 1.0,
                       inline=True) as judge:
            rt = runtime_factory(
                score_url=judge.url, scheduler="threaded", total_steps=2,
                observability=True,
            )
            rt.scheduler.wall_timeout_s = 240.0
            alive = []
            rt.lifecycle.subscribe(
                LifecycleEventKind.REWARDED,
                lambda e: alive.append(rt.reward_server.alive_workers()),
            )
            rt.run()
            assert rt.model_version == 2
            assert judge.submits >= 2 * 2 * 2  # steps x batch x group
        assert rt.tracer.check_conservation(allow_open=True) == []
        assert rt.manager.max_consumed_staleness() <= rt.rcfg.eta
        assert alive and min(alive) == rt.rcfg.reward_workers


# ================================================================= sim mirror
class TestSimVerifierMirror:
    def test_sim_accepts_custom_verifier(self):
        """SimConfig.verifier mirrors RuntimeConfig.verifier: the
        discrete-event simulator scores through the injected verifier
        (hub, fault stack, ...) instead of the constant 1.0."""
        from repro.sim.engine import SimConfig, StaleFlowSim

        reset_traj_ids()
        calls = {"n": 0}

        def counting(p, r):
            calls["n"] += 1
            return 0.5

        cfg = SimConfig(
            n_instances=2, batch_size=4, group_size=2, eta=1,
            total_steps=2, response_mean=500, response_sigma=1.0,
            response_cap=2000, dt=0.5, prompt_len=128,
            train_fixed=5.0, train_per_token=2e-5,
            verifier=FnVerifier(counting),
        )
        r = StaleFlowSim(cfg).run()
        assert r.steps == 2
        assert calls["n"] >= 2 * 4 * 2  # steps x batch x group

    def test_sim_fallback_hub_keeps_protocol_flowing(self):
        from repro.sim.engine import SimConfig, StaleFlowSim

        reset_traj_ids()
        faulty = FaultInjectingVerifier(
            FnVerifier(lambda p, r: 1.0),
            FaultSchedule(seed=2, error_rate=0.2),
        )
        hub = RewardHub(default=faulty, on_failure="fallback",
                        fallback_score=0.0)
        cfg = SimConfig(
            n_instances=2, batch_size=4, group_size=2, eta=1,
            total_steps=2, response_mean=500, response_sigma=1.0,
            response_cap=2000, dt=0.5, prompt_len=128,
            train_fixed=5.0, train_per_token=2e-5, verifier=hub,
        )
        r = StaleFlowSim(cfg).run()
        assert r.steps == 2
        assert faulty.injected() > 0
        assert hub.stats()["routes"]["default"]["fallbacks"] > 0


# ============================================================= tagged prompts
class TestTaggedPrompts:
    def test_trajectory_server_accepts_tagged_source(self):
        from repro.core.trajectory_server import TrajectoryServer
        from repro.data.tasks import ArithmeticDataset

        reset_traj_ids()
        ds = ArithmeticDataset(8, seed=1)
        ts = TrajectoryServer(
            ds.tagged_source(["math", "code"], seed=2),
            capacity_groups=8, group_size=2,
        )
        ts.refill()
        trajs = list(ts.registry.values())
        assert len(trajs) == 16
        tags = {t.task for t in trajs}
        assert tags == {"math", "code"}
        # every member of a group shares its prompt's tag
        for g in ts.groups.values():
            member_tags = {ts.get(tid).task for tid in g.traj_ids}
            assert len(member_tags) == 1

    def test_plain_source_still_works_untagged(self):
        from repro.core.trajectory_server import TrajectoryServer
        from repro.data.tasks import ArithmeticDataset

        reset_traj_ids()
        ds = ArithmeticDataset(4, seed=1)
        ts = TrajectoryServer(ds.prompt_source(), capacity_groups=4)
        ts.refill()
        assert len(ts.registry) == 4
        assert all(t.task == "" for t in ts.registry.values())
