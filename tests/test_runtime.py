"""End-to-end async runtime tests: full rollout->reward->train cycles on a
tiny model, staleness guarantees under load, fault tolerance, elasticity,
checkpoint/restart."""
import numpy as np

from repro.configs import get_arch
from repro.core.types import reset_traj_ids
from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig

ARCH = get_arch("qwen2-1.5b").reduced()


def mk_runtime(**kw):
    reset_traj_ids()
    defaults = dict(
        eta=1, batch_size=2, group_size=2, n_instances=2, max_slots=2,
        max_len=48, max_new_tokens=8, total_steps=3, seed=0,
    )
    defaults.update(kw)
    return AsyncRLRuntime(ARCH, RuntimeConfig(**defaults))


def test_runtime_completes_training_steps():
    rt = mk_runtime(total_steps=3)
    history = rt.run(max_ticks=3000)
    assert len(history) == 3
    assert rt.model_version == 3
    for rec in history:
        assert np.isfinite(rec.loss)
        assert all(0 <= s <= rt.rcfg.eta for s in rec.staleness_hist)
    rt.manager.check_invariants()


def test_runtime_staleness_never_exceeds_eta():
    rt = mk_runtime(eta=2, total_steps=4, n_instances=3)
    rt.run(max_ticks=5000)
    assert rt.model_version == 4
    for hist in rt.manager.consumed_staleness:
        assert all(0 <= s <= 2 for s in hist)


def test_runtime_eta_zero_is_synchronous():
    rt = mk_runtime(eta=0, total_steps=2)
    rt.run(max_ticks=5000)
    assert rt.model_version == 2
    for hist in rt.manager.consumed_staleness:
        assert all(s == 0 for s in hist)


def test_runtime_instance_failure_recovers():
    rt = mk_runtime(total_steps=2, n_instances=2)
    # let some work start
    for _ in range(5):
        rt.tick()
    rt.fail_instance(1)
    # protocol reservations survive; the run must still complete on 1 inst
    rt.manager.check_invariants()
    rt.run(max_ticks=5000)
    assert rt.model_version == 2
    for hist in rt.manager.consumed_staleness:
        assert all(0 <= s <= rt.rcfg.eta for s in hist)


def test_runtime_failed_instance_trajectories_fully_detached():
    """Regression: fail_instance used to return residents to the TS with
    status=RUNNING and a dangling ``instance`` id from the dead replica,
    which misled _abort_members' residency check into mutating speculative
    state for trajectories that were actually TS-resident."""
    rt = mk_runtime(total_steps=2, n_instances=2, max_slots=2)
    returned = []
    for _ in range(40):
        rt.tick()
        if rt.instances[1].snapshot().resident():
            returned = rt.fail_instance(1)
            break
    assert returned, "instance 1 never hosted a trajectory"
    from repro.core.types import TrajStatus

    for tid in returned:
        traj = rt.ts.get(tid)
        assert traj is not None, f"traj {tid} lost on failure"
        assert traj.status != TrajStatus.RUNNING
        assert traj.instance is None
    # the run still completes on the surviving instance
    rt.manager.check_invariants()
    rt.run(max_ticks=5000)
    assert rt.model_version == 2


def test_runtime_elastic_scale_up():
    rt = mk_runtime(total_steps=2, n_instances=1)
    for _ in range(3):
        rt.tick()
    rt.add_instance(7)
    rt.run(max_ticks=5000)
    assert rt.model_version == 2
    # the new instance actually participated
    assert rt.instances[7].decode_steps > 0


def test_runtime_checkpoint_restart_resumes(tmp_path):
    rt = mk_runtime(total_steps=2)
    rt.run(max_ticks=5000)
    rt.checkpoint(str(tmp_path))

    rt2 = mk_runtime(total_steps=4, n_instances=3)  # elastic: 2 -> 3 replicas
    rt2.restore(str(tmp_path))
    assert rt2.model_version == 2
    rt2.run(max_ticks=6000)
    assert rt2.model_version == 4
    rt2.manager.check_invariants()


def test_runtime_vanilla_suite_also_converges_protocol():
    from repro.core import StrategySuite

    rt = mk_runtime(total_steps=2, suite=StrategySuite.vanilla())
    rt.run(max_ticks=5000)
    assert rt.model_version == 2
    for hist in rt.manager.consumed_staleness:
        assert all(0 <= s <= rt.rcfg.eta for s in hist)


def test_runtime_group_filtering_aborts_zero_signal():
    # an untrained model earns all-zero rewards -> EVERY group is
    # zero-signal and DAPO filtering would starve training (faithful but
    # untestable); inject reward variance so some groups carry signal
    def noisy_reward(prompt_ids, response_ids):
        return float((sum(response_ids) + len(prompt_ids)) % 2)

    rt = mk_runtime(total_steps=2, filter_zero_signal=True,
                    reward_fn=noisy_reward)
    rt.run(max_ticks=8000)
    # training completes (filtered groups are replaced by fresh ones)
    assert rt.model_version == 2


def test_runtime_records_is_ratio_metric():
    rt = mk_runtime(total_steps=2)
    history = rt.run(max_ticks=5000)
    for rec in history:
        assert 0.2 < rec.mean_is_ratio < 5.0  # sane IS ratios


def test_runtime_completes_with_paged_kv():
    """Full rollout->reward->train cycles with the block-paged engines:
    the coordinator's cost model runs block-granular accounting and the
    staleness protocol is unaffected by paging/preemption."""
    rt = mk_runtime(total_steps=2, paged_kv=True, kv_block_size=16)
    assert rt.cost_model.block_size == 16
    history = rt.run(max_ticks=3000)
    assert len(history) == 2
    for rec in history:
        assert np.isfinite(rec.loss)
    rt.manager.check_invariants()
    for inst in rt.instances.values():
        inst.allocator.check()


def test_runtime_prefix_sharing_engages_end_to_end():
    """Paged runtime with group sampling: group-affine routing lands whole
    groups on one instance, the engines admit them off ONE shared prompt
    prefill, and training still converges through the same protocol."""
    from repro.core import prefix_routing_strategy

    rt = mk_runtime(
        total_steps=2, paged_kv=True, kv_block_size=16, group_size=2,
        max_slots=4, share_prefix=True,
    )
    assert rt.coordinator.suite.routing is prefix_routing_strategy
    history = rt.run(max_ticks=3000)
    assert len(history) == 2
    hits = sum(inst.shared_prefix_hits for inst in rt.instances.values())
    saved = sum(
        inst.prefill_tokens_saved for inst in rt.instances.values()
    )
    assert hits > 0, "no group ever admitted off a shared prefix"
    assert saved > 0
    rt.manager.check_invariants()
    for inst in rt.instances.values():
        inst.allocator.check()


def test_runtime_share_prefix_off_keeps_plain_routing():
    rt = mk_runtime(paged_kv=True, share_prefix=False)
    from repro.core import routing_strategy

    assert rt.coordinator.suite.routing is routing_strategy
    for inst in rt.instances.values():
        assert not inst.share_prefix
