"""ShardedBackend: one rollout instance spanning a multi-device mesh.

The contract under test (repro.rollout.sharded):

* greedy decode is bit-for-bit equal to the single-device paged engine —
  tokens AND behavior logprobs — across batched admission, CoW prefix
  sharing, cross-wave prefix forks, and pool-exhaustion preemption;
* the paged K/V pool stays head-sharded through prefill scatters, CoW
  copies, and decode steps (per-device bytes = total / shard_count);
* engine, SimBackend, and CostModel report identical per-device kv_cache
  for the same routed group at shard_count > 1;
* the end-to-end runtime runs on sharded instances (RuntimeConfig.
  rollout_shards).

Multi-device paths run in subprocesses with forced host device counts
(the tests/test_distributed.py pattern) so the main pytest process keeps
its single CPU device. Validation-only tests run in-process.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.snapshot import InstanceSnapshot
from repro.distributed.sharding import validate_rollout_shards
from repro.rollout.backend import BACKENDS

NO_EOS = -1


def _cfg(n_heads=4, n_kv_heads=2):
    from repro.configs import get_arch

    return dataclasses.replace(
        get_arch("qwen2-1.5b").reduced(),
        n_heads=n_heads,
        n_kv_heads=n_kv_heads,
        head_dim=16,
        d_model=n_heads * 16,
    )


def _mk_trajs():
    """Workload mixing every admission path: two 3-member groups (one
    shared prefill + CoW tails each), a straggler that forks a resident
    prefix cross-wave, and plain singles."""
    from repro.core.types import Trajectory

    out = []
    rng = np.random.RandomState(0)
    tid = 0
    prompts = {}
    for gid in range(2):
        prompts[gid] = list(rng.randint(3, 200, 13 + 5 * gid))
        for _ in range(3):
            out.append(
                Trajectory(
                    traj_id=tid,
                    prompt=list(prompts[gid]),
                    group_id=gid,
                    max_new_tokens=18,
                )
            )
            tid += 1
    # straggler: same group/prompt as group 0, routed a later wave
    out.append(
        Trajectory(
            traj_id=tid,
            prompt=list(prompts[0]),
            group_id=0,
            max_new_tokens=18,
        )
    )
    tid += 1
    for i in range(3):
        out.append(
            Trajectory(
                traj_id=tid,
                prompt=list(rng.randint(3, 200, 7 + i)),
                max_new_tokens=18,
            )
        )
        tid += 1
    return out


def run_scenario(shard_count, temperature=0.0, kv_pool_blocks=14, n_kv_heads=2):
    """Drive one engine over the mixed workload; the tight pool forces
    preemption mid-decode. Returns (per-traj results, telemetry)."""
    import jax

    from repro.models import model as M
    from repro.rollout.backend import create_backend

    cfg = _cfg(n_kv_heads=n_kv_heads)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    kw = dict(
        cfg=cfg,
        params=params,
        version=0,
        max_slots=4,
        max_len=64,
        temperature=temperature,
        eos_id=NO_EOS,
        seed=3,
        paged=True,
        kv_block_size=8,
        kv_pool_blocks=kv_pool_blocks,
        share_prefix=True,
    )
    if shard_count > 1:
        inst = create_backend("sharded", 0, shard_count=shard_count, **kw)
    else:
        inst = create_backend("jax", 0, **kw)
    ts = _mk_trajs()
    inst.route_many(ts[:6])
    done = []
    for step in range(200):
        done.extend(inst.step())
        inst.allocator.check()
        if step == 3:
            inst.route_many(ts[6:])
        if len(done) == len(ts):
            break
    out = {t.traj_id: (list(t.response), list(t.behavior_logprobs)) for t in done}
    telemetry = {
        "preemptions": inst.preemptions,
        "shared_prefix_hits": inst.shared_prefix_hits,
        "prefill_tokens_saved": inst.prefill_tokens_saved,
        "kv_bytes": inst.kv_bytes(),
    }
    return out, telemetry, inst


def run_runtime_smoke(shards):
    """One training step of the full async runtime on sharded instances."""
    from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig

    cfg = _cfg(n_heads=2, n_kv_heads=2)
    rcfg = RuntimeConfig(
        batch_size=2,
        group_size=2,
        n_instances=2,
        max_slots=4,
        max_len=64,
        max_new_tokens=6,
        total_steps=1,
        paged_kv=True,
        kv_block_size=8,
        rollout_shards=shards,
    )
    rt = AsyncRLRuntime(cfg, rcfg)
    history = rt.run(max_ticks=200)
    return len(history)


# ------------------------------------------------------------- in-process
def test_validate_rollout_shards_rejects_nondivisible_heads():
    validate_rollout_shards(2, n_heads=4, n_kv_heads=2)
    with pytest.raises(ValueError, match="divide"):
        validate_rollout_shards(3, n_heads=4, n_kv_heads=2)
    with pytest.raises(ValueError, match="divide"):
        validate_rollout_shards(4, n_heads=4, n_kv_heads=2)
    with pytest.raises(ValueError, match=">= 1"):
        validate_rollout_shards(0, n_heads=4, n_kv_heads=2)


def test_sharded_backend_registered():
    assert "sharded" in BACKENDS


def test_sharded_backend_requires_paged():
    from repro.rollout.sharded import ShardedBackend

    with pytest.raises(ValueError, match="paged"):
        ShardedBackend(0, _cfg(), None, 0, shard_count=2, paged=False)


def test_make_rollout_mesh_insufficient_devices_message():
    from repro.launch.mesh import make_rollout_mesh

    with pytest.raises(ValueError, match="device_count"):
        make_rollout_mesh(99999)
    with pytest.raises(ValueError, match=">= 1"):
        make_rollout_mesh(0)


def test_sim_backend_reports_per_device_bytes():
    """SimBackend at shard_count=S reports exactly 1/S of the unsharded
    per-instance bytes — the pool spreads over head shards."""
    from repro.core import PAPER_H20_QWEN3_30B
    from repro.core.types import Trajectory
    from repro.rollout.backend import SimBackend

    cm1 = dataclasses.replace(
        PAPER_H20_QWEN3_30B, block_size=16, kv_budget=float("inf")
    )
    cm4 = dataclasses.replace(cm1, shard_count=4)

    def route(cm):
        sim = SimBackend(0, cm)
        for tid, plen in ((10, 6), (11, 20)):
            t = Trajectory(
                traj_id=tid,
                prompt=list(np.random.RandomState(tid).randint(3, 17, plen)),
                max_new_tokens=8,
            )
            t.sim_target_len = 8
            sim.route(t, 0.0)
        return sim.snapshot()

    s1, s4 = route(cm1), route(cm4)
    assert s4.kv_cache == s1.kv_cache / 4
    assert s4.shard_count == 4 and s1.shard_count == 1


def test_snapshot_discard_scales_by_shard_count():
    """discard() releases per-device bytes: k5 is the pod-total per-token
    footprint, the snapshot basis is one device."""
    k5 = 128.0
    s = InstanceSnapshot(
        inst_id=0,
        kv_cache=k5 * 32 / 4,
        run_trajs={1},
        traj_lengths={1: 32},
        shard_count=4,
    )
    s.discard([1], bytes_per_token=k5, block_size=16)
    assert s.kv_cache == 0.0


# ------------------------------------------------------------ subprocess
def _run_subprocess(code: str, devices: int = 8) -> str:
    prog = (
        f"import os; os.environ['XLA_FLAGS']="
        f"'--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(code)
    )
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        text=True,
        timeout=480,
        env={
            "PYTHONPATH": "src",
            "PATH": "/usr/bin:/bin",
            # without this the child jax probes for a TPU backend (libtpu
            # ships in the image) and stalls minutes on metadata retries
            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
        },
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_greedy_bitwise_equivalence_subprocess():
    """The acceptance bit: greedy decode on a 4-device ShardedBackend is
    bit-for-bit equal (tokens + behavior logprobs) to the single-device
    paged engine across admission, CoW prefix sharing, and preemption."""
    out = _run_subprocess(
        """
        from tests.test_sharded_backend import run_scenario

        ref, tel_ref, _ = run_scenario(1, n_kv_heads=4)
        shd, tel_shd, inst = run_scenario(4, n_kv_heads=4)
        assert set(ref) == set(shd), (sorted(ref), sorted(shd))
        for tid in sorted(ref):
            assert ref[tid][0] == shd[tid][0], (tid, "tokens diverged")
            assert ref[tid][1] == shd[tid][1], (tid, "logprobs diverged")
        # the tight pool preempted on both engines, identically
        assert tel_ref["preemptions"] > 0
        assert tel_ref["preemptions"] == tel_shd["preemptions"]
        assert tel_ref["shared_prefix_hits"] == tel_shd["shared_prefix_hits"]
        assert (
            tel_ref["prefill_tokens_saved"] == tel_shd["prefill_tokens_saved"]
        )
        # group 0 shares at admission (+2); later members fork resident
        # prefixes cross-wave — slot pressure decides how many
        assert tel_ref["shared_prefix_hits"] >= 3
        # per-device accounting: the sharded pool reports 1/4 the bytes
        assert tel_shd["kv_bytes"] == tel_ref["kv_bytes"] / 4
        # the pool stayed head-sharded end to end
        spec = inst.cache["k"].sharding.spec
        assert spec[3] == "tensor", spec
        shard_shapes = set(inst.shard_sizes())
        full = inst.cache["k"].shape
        assert shard_shapes == {full[:3] + (full[3] // 4,) + full[4:]}
        print("BITWISE_OK")
        """,
        devices=8,
    )
    assert "BITWISE_OK" in out


def test_sharded_stochastic_bitwise_equivalence_subprocess():
    """Same-occupancy stochastic decode also matches bitwise: the gathers
    reconstruct exact logits, so sampling consumes identical
    distributions and identical keys."""
    out = _run_subprocess(
        """
        from tests.test_sharded_backend import run_scenario

        ref, _, _ = run_scenario(1, temperature=0.7)
        shd, _, _ = run_scenario(2, temperature=0.7)
        assert set(ref) == set(shd)
        for tid in sorted(ref):
            assert ref[tid] == shd[tid], tid
        print("STOCH_OK")
        """,
        devices=8,
    )
    assert "STOCH_OK" in out


def test_sharded_engine_sim_costmodel_kv_parity_subprocess():
    """Engine / SimBackend / CostModel agree on per-device kv_cache for
    the same routed group at shard_count=2 (the coordinator's one memory
    picture, now per device)."""
    out = _run_subprocess(
        """
        import dataclasses

        import jax
        import numpy as np

        from repro.core import PAPER_H20_QWEN3_30B
        from repro.core.types import Trajectory
        from repro.models import model as M
        from repro.rollout.backend import SimBackend, create_backend
        from tests.test_sharded_backend import _cfg

        cfg = _cfg()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        bs, plen, g, shards = 8, 19, 3, 2
        k5 = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 4
        cm = dataclasses.replace(
            PAPER_H20_QWEN3_30B, k5=float(k5), block_size=bs,
            kv_budget=float("inf"), shard_count=shards,
        )
        sim = SimBackend(0, cm, share_prefix=True)
        eng = create_backend(
            "sharded", 1, cfg=cfg, params=params, version=0,
            shard_count=shards, max_slots=4, max_len=64, temperature=0.0,
            paged=True, kv_block_size=bs, share_prefix=True,
        )
        prompt = list(np.random.RandomState(7).randint(3, 17, plen))

        def group(base):
            return [
                Trajectory(
                    traj_id=base + i, prompt=list(prompt), group_id=0,
                    max_new_tokens=50,
                )
                for i in range(g)
            ]

        sim.route_many(group(80), 0.0)
        eng.route_many(group(80), 0.0)
        n_full = plen // bs
        # lazy CoW (the default): shared prompt blocks once, plus ONE
        # shared tail block — nobody has decoded yet, so nobody owns a
        # private copy. Per-device bytes at shard_count=2.
        expected = k5 * bs * (n_full + 1) / shards
        assert sim.snapshot().kv_cache == expected
        assert eng.snapshot().kv_cache == expected
        assert cm.group_kv_bytes_for(
            plen, [plen + 1] * g, undiverged=g
        ) == expected
        # the default (eager/worst-case) view admission decisions use
        assert cm.group_kv_bytes_for(plen, [plen + 1] * g) == (
            k5 * bs * (n_full + g) / shards
        )
        assert sim.snapshot().shard_count == shards
        assert eng.snapshot().shard_count == shards
        # per-member interrupts release per-device exclusive bytes,
        # identically on both, down to zero with the last co-owner
        sim.interrupt([80], 1.0)
        eng.interrupt([80], 1.0)
        assert sim.snapshot().kv_cache == eng.snapshot().kv_cache
        sim.interrupt([81, 82], 1.0)
        eng.interrupt([81, 82], 1.0)
        assert sim.snapshot().kv_cache == 0
        assert eng.snapshot().kv_cache == 0
        print("PARITY_OK")
        """,
        devices=8,
    )
    assert "PARITY_OK" in out


@pytest.mark.slow
def test_runtime_on_sharded_instances_subprocess():
    """RuntimeConfig.rollout_shards wires the sharded backend through the
    full async runtime: coordinator cycles, pulls (params re-sharded onto
    the mesh), rewards, and a training step all execute."""
    out = _run_subprocess(
        """
        from tests.test_sharded_backend import run_runtime_smoke

        assert run_runtime_smoke(2) >= 1
        print("RUNTIME_OK")
        """,
        devices=8,
    )
    assert "RUNTIME_OK" in out
