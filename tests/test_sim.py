"""Simulator tests: the cluster-scale reproduction engine behind the
Fig. 13/15/16/17/18 benchmarks. The control plane is the REAL protocol;
these tests assert the paper's qualitative claims hold in simulation."""
import dataclasses


from repro.core import PAPER_H20_QWEN3_30B, StrategySuite
from repro.core.types import reset_traj_ids
from repro.sim.baselines import OneStepSim, SyncSim
from repro.sim.engine import SimConfig, StaleFlowSim


def base_cfg(**kw):
    d = dict(
        n_instances=4, batch_size=8, group_size=4, eta=1, total_steps=3,
        response_mean=3000, response_sigma=1.2, response_cap=20000,
        dt=0.5, prompt_len=2048, train_fixed=20.0, train_per_token=2e-5,
    )
    d.update(kw)
    return SimConfig(**d)


def run(cfg):
    reset_traj_ids()
    return StaleFlowSim(cfg).run()


def test_sim_completes_and_counts_tokens():
    r = run(base_cfg())
    assert r.steps == 3
    assert r.total_tokens > 3 * 8 * 4 * 2048  # at least the prompts
    assert r.throughput > 0


def test_sim_staleness_bounded_and_exploited():
    cfg = base_cfg(eta=3, total_steps=5)
    r = run(cfg)
    flat = [s for h in r.staleness_hists for s in h]
    assert all(0 <= s <= 3 for s in flat)
    # Fig. 18: once pipelined, staleness > 0 is actually used
    assert any(s > 0 for s in flat)


def test_sim_async_beats_sync_and_onestep():
    """Fig. 13 qualitative ordering: staleflow > one-step > sync."""
    cfg = base_cfg(eta=2, total_steps=4)
    r_sf = run(cfg)
    reset_traj_ids()
    r_os = OneStepSim(cfg).run()
    reset_traj_ids()
    r_sy = SyncSim(cfg).run()
    assert r_sf.throughput > r_os.throughput > r_sy.throughput
    assert r_sf.throughput / r_sy.throughput > 1.5  # paper: 2.01x avg


def test_sim_throughput_grows_with_eta():
    """Fig. 3/13: larger staleness bounds buy throughput."""
    t = {}
    for eta in (0, 1, 3):
        t[eta] = run(base_cfg(eta=eta, total_steps=4)).throughput
    assert t[1] > t[0]
    assert t[3] > t[1]


def test_sim_staleflow_beats_inflight_when_kv_bound():
    """Fig. 13/16: under KV pressure + large eta, throughput-oriented
    strategies beat the vanilla (in-flight-limit == VeRL-Async) ones."""
    cm = dataclasses.replace(
        PAPER_H20_QWEN3_30B, kv_budget=75_000 * PAPER_H20_QWEN3_30B.k5
    )
    cfg = base_cfg(
        n_instances=8, batch_size=16, group_size=8, eta=3, total_steps=6,
        response_mean=4000, response_sigma=1.6, response_cap=40000,
        cost_model=cm,
    )
    r_sf = run(cfg)
    reset_traj_ids()
    r_if = StaleFlowSim(
        dataclasses.replace(cfg, suite=StrategySuite.vanilla())
    ).run()
    assert r_sf.throughput > 1.05 * r_if.throughput


def test_sim_instance_load_telemetry():
    r = run(base_cfg())
    assert len(r.instance_load) > 2
    t0, loads0 = r.instance_load[0]
    assert set(loads0) == set(range(4))


def test_sim_group_redundancy_no_speculative_deadlock():
    """Regression: group-level surplus aborts bypass the command cycle and
    MUST update the speculative state P (Table 1), else Eq. 1 rejects every
    later snapshot and the coordinator deadlocks."""
    cfg = base_cfg(total_steps=3, group_size=4)
    r = StaleFlowSim(dataclasses.replace(cfg, group_redundancy=1)).run()
    assert r.steps == 3
    assert r.total_time < cfg.max_sim_time


def test_sim_redundancy_reduces_step_time():
    """Fig. 25: batch-level redundancy drops long-tail trajectories and
    shortens steps (tokens/step decreases, throughput rises modestly)."""
    cfg = base_cfg(total_steps=4, response_sigma=1.6)
    r0 = run(cfg)
    reset_traj_ids()
    r1 = StaleFlowSim(dataclasses.replace(cfg, batch_redundancy=2)).run()
    assert r1.total_time < r0.total_time
    assert r1.total_tokens <= r0.total_tokens  # tail dropped


# ------------------------------------------------------ streaming pipeline
def test_sim_streaming_completes_with_staleness_bound():
    """Streaming (incremental admission + partial consume) drives the same
    real control plane: the run completes and every consumed sample
    respects eta."""
    r = run(base_cfg(streaming=True, stream_min_fill=2, total_steps=4))
    assert r.steps == 4
    flat = [s for h in r.staleness_hists for s in h]
    assert flat and all(0 <= s <= 1 for s in flat)
    # partial consumes are allowed to ship fewer than batch_size groups
    assert all(len(h) <= 8 for h in r.staleness_hists)


def test_sim_streaming_no_slower_than_barrier():
    """The point of killing the cycle barrier: per-event admission refills
    freed capacity between the (rarer) full cycles, so streaming routes at
    least as much work per unit time."""
    cfg = base_cfg(eta=2, total_steps=4, coordinator_interval=4.0)
    r_barrier = run(cfg)
    r_stream = run(dataclasses.replace(cfg, streaming=True))
    assert r_stream.route_count >= r_barrier.route_count
    assert r_stream.total_time <= r_barrier.total_time * 1.1
