"""Protocol tests: virtual staleness buffers (paper §4, Fig. 7/8)."""
import random

import pytest
from _optional import given, settings, st

from repro.core.staleness import (
    BufferState,
    EntryState,
    StalenessManager,
    StalenessViolation,
)


def test_reserve_backward_scan_picks_latest_buffer():
    m = StalenessManager(batch_size=2, eta=2)
    # worst-case Reserve: version 0 with eta=2 -> buffer 2 (latest legal)
    assert m.reserve(key=1, version=0) == 2
    assert m.reserve(key=2, version=0) == 2
    # buffer 2 is now full -> falls back to buffer 1
    assert m.reserve(key=3, version=0) == 1
    m.check_invariants()


def test_reserve_respects_eta_zero():
    m = StalenessManager(batch_size=1, eta=0)
    assert m.reserve(1, 0) == 0
    with pytest.raises(StalenessViolation):
        m.reserve(2, 0)  # buffer 0 full; version 0 cannot go to buffer 1


def test_occupy_moves_to_earliest_buffer():
    m = StalenessManager(batch_size=2, eta=2)
    m.reserve(1, 0)          # -> buffer 2
    v = m.occupy(1)          # greedy forward -> buffer 0
    assert v == 0
    info = m.entry_info(1)
    assert info == (0, EntryState.OCCUPIED, 0)
    m.check_invariants()


def test_consume_requires_ready_and_advances_version():
    m = StalenessManager(batch_size=2, eta=1)
    m.reserve(1, 0)
    m.reserve(2, 0)
    assert m.consume() is None          # nothing occupied yet
    m.occupy(1)
    m.occupy(2)
    assert m.ready()
    keys = m.consume()
    assert sorted(keys) == [1, 2]
    assert m.train_version == 1
    assert m.in_flight() == 0
    m.check_invariants()


def test_discriminator_rejects_when_range_full():
    m = StalenessManager(batch_size=1, eta=1)
    m.reserve(1, 0)  # buffer 1
    m.reserve(2, 0)  # buffer 0
    assert not m.can_reserve(0)          # buffers 0..1 full
    assert m.can_reserve(1)              # buffer 2 reachable from version 1
    assert m.min_admissible_version(at_least=0) == 1


def test_entry_movement_cascade_fig7_right():
    """Deleting a reserved entry pulls earlier reserved entries forward."""
    m = StalenessManager(batch_size=1, eta=2)
    # A: version 0 -> buffer 2 (backward scan)
    m.reserve(10, 0)
    # B: version 0 -> buffer 1
    m.reserve(11, 0)
    # C: version 0 -> buffer 0
    m.reserve(12, 0)
    # A completes: per Fig. 7 right, the *earliest* reserved entry legal at
    # buffer 2 (C, in buffer 0) is pulled into A's hole; buffer 0 frees up
    # and A occupies it (greedy forward scan). Reserved entries end up
    # pushed late, occupied entries early.
    v = m.occupy(10)
    assert v == 0
    assert m.entry_info(12)[0] == 2      # C (earliest reserved) moved late
    assert m.entry_info(11)[0] == 1      # B untouched
    assert m.entry_info(10) == (0, EntryState.OCCUPIED, 0)
    m.check_invariants()


def test_buffer_states_waiting_ready_stuck():
    m = StalenessManager(batch_size=2, eta=0)
    assert m._buffer(0).state == BufferState.WAITING
    m.reserve(1, 0)
    m.reserve(2, 0)
    assert m._buffer(0).state == BufferState.STUCK
    m.occupy(1)
    m.occupy(2)
    assert m._buffer(0).state == BufferState.READY


def test_abort_pulls_occupied_forward():
    m = StalenessManager(batch_size=1, eta=2)
    m.reserve(1, 0)
    m.occupy(1)              # occupied at buffer 0
    m.reserve(2, 0)
    m.occupy(2)              # buffer 0 full -> occupies buffer 1
    assert m.entry_info(2)[0] == 1
    m.abort(1)               # free buffer 0 -> entry 2 moves forward
    assert m.entry_info(2)[0] == 0
    assert not m.is_tracked(1)
    m.check_invariants()


def test_abort_is_idempotent():
    m = StalenessManager(batch_size=2, eta=1)
    m.reserve(1, 0)
    m.abort(1)
    m.abort(1)  # no raise
    assert m.in_flight() == 0


def test_batch_redundancy_surplus_reported():
    m = StalenessManager(batch_size=2, eta=0, batch_redundancy=1)
    for k in range(3):
        m.reserve(k, 0)
    m.occupy(0)
    m.occupy(1)
    # batch_size occupied; key 2 still reserved -> surplus
    assert m.surplus_keys() == [2]
    keys = m.consume()
    assert sorted(keys) == [0, 1]
    m.check_invariants()


def test_lower_version_relocates_entry():
    m = StalenessManager(batch_size=2, eta=1)
    m.reserve(1, 2)                       # group min starts at 2 -> buffer 3
    assert m.entry_info(1)[0] == 3
    assert m.lower_version(1, 1)          # new member at version 1
    v_buf, _, version = m.entry_info(1)
    assert version == 1 and v_buf <= 2    # relocated to satisfy 1 + 1 >= v_buf
    m.check_invariants()


def test_staleness_distribution_telemetry():
    m = StalenessManager(batch_size=2, eta=3)
    m.reserve(1, 0)
    m.reserve(2, 0)
    m.occupy(1)
    m.occupy(2)
    m.consume()
    assert m.consumed_staleness == [[0, 0]]  # consumed at train_version 0


def test_full_pipeline_multiple_steps():
    """Drive several training steps with mixed versions; bound always holds."""
    m = StalenessManager(batch_size=4, eta=2)
    key = 0
    for step in range(8):
        # producers run at the current trained version
        while not m.ready():
            v = m.min_admissible_version(at_least=max(0, m.train_version - m.eta))
            m.reserve(key, v)
            m.occupy(key)
            key += 1
            m.check_invariants()
        batch = m.consume()
        assert len(batch) == 4
        for hist in m.consumed_staleness:
            assert all(0 <= s <= m.eta for s in hist)
    assert m.train_version == 8


# ---------------------------------------------------------------- property
@settings(max_examples=200, deadline=None)
@given(
    batch_size=st.integers(1, 4),
    eta=st.integers(0, 3),
    seed=st.integers(0, 2**32 - 1),
    n_ops=st.integers(1, 120),
)
def test_random_op_sequences_never_violate_bound(batch_size, eta, seed, n_ops):
    """Fuzz Reserve/Occupy/Consume/Abort: the invariant must always hold and
    consumed staleness must never exceed eta."""
    rng = random.Random(seed)
    m = StalenessManager(batch_size=batch_size, eta=eta)
    reserved, occupied = [], []
    next_key = 0
    for _ in range(n_ops):
        op = rng.choice(["reserve", "occupy", "consume", "abort"])
        if op == "reserve":
            v = m.min_admissible_version(
                at_least=max(0, m.train_version - eta + rng.randint(0, eta or 1))
            )
            if v is not None and m.can_reserve(v):
                m.reserve(next_key, v)
                reserved.append(next_key)
                next_key += 1
        elif op == "occupy" and reserved:
            k = reserved.pop(rng.randrange(len(reserved)))
            if m.is_tracked(k):
                m.occupy(k)
                occupied.append(k)
        elif op == "consume":
            keys = m.consume()
            if keys:
                occupied = [k for k in occupied if k not in set(keys)]
                # consume may silently drop leftovers that no longer fit;
                # resync our mirror of reserved keys
                reserved = [k for k in reserved if m.is_tracked(k)]
                occupied = [k for k in occupied if m.is_tracked(k)]
        elif op == "abort" and (reserved or occupied):
            pool = reserved if (reserved and (not occupied or rng.random() < 0.5)) else occupied
            k = pool.pop(rng.randrange(len(pool)))
            m.abort(k)
        m.check_invariants()
    for hist in m.consumed_staleness:
        assert all(0 <= s <= eta for s in hist)


# ------------------------------------------- streaming partial consumption
def test_ready_partial_requires_min_occupied():
    m = StalenessManager(batch_size=4, eta=1)
    assert not m.ready(2)  # empty buffer is never consumable
    for k in range(2):
        m.reserve(k, 0)
        m.occupy(k)
    assert not m.ready()       # full-batch rule: 2 < 4
    assert not m.ready(3)      # below the partial floor
    assert m.ready(2)          # at the partial floor
    assert not m.ready(0)      # <= 0 disables partial mode
    m.check_invariants()


def test_partial_consume_returns_occupied_and_advances_floor():
    m = StalenessManager(batch_size=4, eta=1)
    for k in range(2):
        m.reserve(k, 0)
        m.occupy(k)
    keys = m.consume(2)
    assert sorted(keys) == [0, 1]
    assert m.train_version == 1
    # partial consumes record real staleness samples and respect eta
    assert m.consumed_staleness[-1] == [0, 0]
    m.check_invariants()


def test_partial_consume_triggers_at_eta_bound():
    """An occupied entry at the eta bound cannot get staler — the partial
    batch ships even below min_occupied."""
    m = StalenessManager(batch_size=4, eta=1)
    m.reserve(1, 0)
    m.occupy(1)  # occupied at the floor buffer, staleness-if-consumed 0
    assert not m.ready(2)       # 1 < min_occupied=2 and not at the bound
    assert m.consume(1) == [1]  # partial floor met -> floor advances to 1
    assert m.train_version == 1
    # a version-0 entry under floor 1: staleness 1 == eta, cannot worsen
    m.reserve(10, 0)
    m.occupy(10)
    assert m.ready(2)  # eta-bound rule overrides min_occupied
    assert m.consume(2) == [10]
    assert m.consumed_staleness[-1] == [1]
    m.check_invariants()


def test_partial_consume_evicts_unrehomeable_leftovers():
    """Leftover entries whose version is illegal under the advanced floor
    are reported via take_evicted (the coordinator Aborts the payloads)."""
    m = StalenessManager(batch_size=2, eta=0)
    # buffer 0: one occupied (consumable partial), one reserved straggler
    m.reserve(1, 0)
    m.occupy(1)
    m.reserve(2, 0)
    keys = m.consume(1)
    assert keys == [1]
    assert m.train_version == 1
    # key 2 (version 0, eta 0) cannot live in buffer >= 1 -> evicted
    assert m.take_evicted() == [2]
    assert m.take_evicted() == []  # drained
    assert not m.is_tracked(2)
    m.check_invariants()


def test_partial_consume_never_violates_staleness_bound():
    """Fuzz partial consumption: the eta bound holds for every consumed
    sample regardless of min_occupied interleavings."""
    rng = random.Random(7)
    m = StalenessManager(batch_size=3, eta=2)
    next_key = 0
    for _ in range(200):
        op = rng.choice(["produce", "consume", "consume_partial"])
        if op == "produce":
            v = m.min_admissible_version(
                at_least=max(0, m.train_version - m.eta)
            )
            if v is not None and m.can_reserve(v):
                m.reserve(next_key, v)
                m.occupy(next_key)
                next_key += 1
        elif op == "consume":
            m.consume()
        else:
            m.consume(rng.randint(1, 3))
        m.take_evicted()
        m.check_invariants()
    for hist in m.consumed_staleness:
        assert all(0 <= s <= m.eta for s in hist)
