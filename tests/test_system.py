"""End-to-end system behaviour tests: the paper's headline properties
exercised through the full stack (protocol + coordinator + real rollout +
reward + training), complementing the per-module suites."""

import numpy as np

from repro.configs import get_arch
from repro.core import StrategyConfig
from repro.core.types import reset_traj_ids
from repro.runtime.async_runtime import AsyncRLRuntime, RuntimeConfig
from repro.sim.engine import SimConfig, StaleFlowSim

ARCH = get_arch("qwen2-1.5b").reduced()


def test_end_to_end_async_rl_trains_and_respects_bound():
    """The complete Fig. 6 data flow on a real model: trajectories stream
    TS -> rollout -> reward -> staleness buffers -> training -> PS -> Pull,
    with the eta bound holding at every consumed batch."""
    reset_traj_ids()
    rt = AsyncRLRuntime(
        ARCH,
        RuntimeConfig(
            eta=2, batch_size=3, group_size=2, n_instances=2, max_slots=3,
            max_len=48, max_new_tokens=8, total_steps=4, lr=1e-3,
        ),
    )
    hist = rt.run(max_ticks=8000)
    assert rt.model_version == 4
    for rec in hist:
        assert np.isfinite(rec.loss)
        assert max(rec.staleness_hist) <= 2
    # the coordinator actually coordinated
    assert rt.coordinator.stats.commands["Route"] >= 4 * 3 * 2
    assert rt.coordinator.stats.commands["Pull"] >= 1
    rt.manager.check_invariants()


def test_migration_through_ts_preserves_trajectory_payloads():
    """Partial rollout via the TS: force migration with an aggressive
    throughput-gap threshold; interrupted trajectories resume elsewhere and
    finish with contiguous segment provenance."""
    reset_traj_ids()
    rt = AsyncRLRuntime(
        ARCH,
        RuntimeConfig(
            eta=1, batch_size=2, group_size=2, n_instances=3, max_slots=2,
            max_len=48, max_new_tokens=10, total_steps=2,
            strategy_cfg=StrategyConfig(mu=0.3, phi_wait=0, phi_throughput=1.01),
        ),
    )
    rt.run(max_ticks=8000)
    assert rt.model_version == 2
    assert rt.coordinator.stats.commands["Interrupt"] > 0  # migration happened
    # every consumed trajectory's segments sum to its generated length
    for t in rt._retired.values():
        assert sum(n for _, n in t.segments) == t.n_generated


def test_sim_and_runtime_share_protocol_semantics():
    """The simulator drives the same coordinator/protocol classes as the
    live runtime: identical staleness guarantees under both data planes."""
    reset_traj_ids()
    sim = StaleFlowSim(SimConfig(
        n_instances=4, batch_size=8, group_size=4, eta=2, total_steps=4,
        response_mean=2000, response_cap=16000, dt=0.5,
    ))
    r = sim.run()
    assert r.steps == 4
    flat = [s for h in r.staleness_hists for s in h]
    assert flat and max(flat) <= 2
    sim.manager.check_invariants()


def test_snapshot_command_cycle_rejects_stale_snapshots_live():
    """Eq. 1 in the live loop: feeding the coordinator the same snapshot
    twice (commands outstanding) must be rejected, not double-executed."""
    reset_traj_ids()
    rt = AsyncRLRuntime(
        ARCH,
        RuntimeConfig(eta=1, batch_size=2, group_size=2, n_instances=1,
                      max_slots=2, max_len=48, max_new_tokens=6, total_steps=1),
    )
    snaps = rt._snapshots()
    cmds = rt.coordinator.step(snaps, rt.ps.version)
    assert cmds
    again = rt.coordinator.step(snaps, rt.ps.version)  # stale: not re-observed
    assert again == []
    assert rt.coordinator.stats.snapshots_rejected == 1


def test_eta_sweep_is_ratio_drift_monotone():
    """More staleness tolerance -> behavior/current policy gap grows (the
    convergence-vs-throughput tradeoff of Fig. 3, at mechanism level)."""
    drifts = {}
    for eta in (0, 3):
        reset_traj_ids()
        rt = AsyncRLRuntime(
            ARCH,
            RuntimeConfig(
                eta=eta, batch_size=3, group_size=2, n_instances=2,
                max_slots=3, max_len=48, max_new_tokens=8, total_steps=3,
                lr=5e-3, seed=1,
            ),
        )
        hist = rt.run(max_ticks=8000)
        stal = [s for h in hist for s in h.staleness_hist]
        drifts[eta] = max(stal) if stal else 0
    # eta=0 is perfectly on-policy; eta=3 actually exploits staleness
    assert drifts[0] == 0
    assert drifts[3] >= 1
