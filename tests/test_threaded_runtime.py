"""Threaded scheduler: real concurrency with the paper's guarantees.

Tier-1 smoke: a tiny model trains to completion with rollout instances,
reward workers, the coordinator, and the trainer on separate threads —
and the staleness bound eta holds on EVERY consumed batch, protocol
invariants checked under concurrency. Plus: elasticity (fail/add instance
mid-decode) and cooperative-scheduler determinism (run() == manual ticks,
fixed seed reproducibility).
"""
import threading
import time

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.types import reset_traj_ids
from repro.runtime.async_runtime import (
    AsyncRLRuntime,
    CooperativeScheduler,
    RuntimeConfig,
)

ARCH = get_arch("qwen2-1.5b").reduced()


def mk_runtime(**kw):
    reset_traj_ids()
    defaults = dict(
        eta=1, batch_size=2, group_size=2, n_instances=2, max_slots=2,
        max_len=48, max_new_tokens=8, total_steps=3, seed=0,
    )
    defaults.update(kw)
    return AsyncRLRuntime(ARCH, RuntimeConfig(**defaults))


# ------------------------------------------------------------ threaded smoke
def test_threaded_scheduler_trains_with_staleness_bound(lock_witnessed):
    """CI threaded-runtime smoke: fixed seed, small model, eta enforced on
    every consumed batch under real thread interleavings — with the lock
    witness recording every acquisition (clean graph asserted at
    teardown)."""
    rt = mk_runtime(scheduler="threaded", total_steps=2)
    rt.scheduler.wall_timeout_s = 240.0
    history = rt.run()
    assert rt.model_version == 2
    assert len(history) == 2
    for rec in history:
        assert np.isfinite(rec.loss)
        assert all(0 <= s <= rt.rcfg.eta for s in rec.staleness_hist)
    assert rt.manager.max_consumed_staleness() <= rt.rcfg.eta
    rt.manager.check_invariants()
    # the reward phase really ran as a service
    stats = rt.reward_server.stats()
    assert stats["scored"] >= 2 * rt.rcfg.batch_size * rt.rcfg.group_size
    # Push went through the background pusher (overlap path)
    assert rt.ps.version == rt.model_version
    # the witness really tracked the run (teardown asserts it's clean)
    assert lock_witnessed.acquires > 0 and lock_witnessed.emits > 0


def test_threaded_scheduler_respects_larger_eta():
    rt = mk_runtime(scheduler="threaded", eta=2, total_steps=2,
                    n_instances=2)
    rt.scheduler.wall_timeout_s = 240.0
    rt.run()
    assert rt.model_version == 2
    for hist in rt.manager.consumed_staleness:
        assert all(0 <= s <= 2 for s in hist)
    rt.manager.check_invariants()


# --------------------------------------------------- elasticity mid-decode
@pytest.mark.slow
def test_threaded_elasticity_fail_and_add_mid_decode(lock_witnessed):
    """fail_instance / add_instance while instance threads are actively
    decoding: protocol invariants hold after every transition and the run
    still completes on the reshaped fleet."""
    rt = mk_runtime(scheduler="threaded", total_steps=3, n_instances=2)
    rt.scheduler.wall_timeout_s = 280.0
    runner = threading.Thread(target=rt.run, daemon=True)
    runner.start()
    # wait until instance 1 is actually decoding
    deadline = time.perf_counter() + 120
    while time.perf_counter() < deadline:
        if rt.instances[1].decode_steps > 0 and rt.model_version >= 1:
            break
        time.sleep(0.05)
    assert rt.instances[1].decode_steps > 0, "instance 1 never decoded"

    returned = rt.fail_instance(1)
    rt.manager.check_invariants()  # transition 1: replica loss
    from repro.core.types import TrajStatus

    for tid in returned:
        traj = rt.ts.get(tid)
        if traj is not None:
            assert traj.status != TrajStatus.RUNNING
            assert traj.instance is None

    rt.add_instance(7)
    rt.manager.check_invariants()  # transition 2: elastic scale-up

    runner.join(timeout=280)
    assert not runner.is_alive(), "threaded run did not finish"
    assert rt.model_version == 3
    rt.manager.check_invariants()
    assert rt.manager.max_consumed_staleness() <= rt.rcfg.eta
    # the replacement instance was picked up by the supervisor
    assert 7 in rt.instances


# ------------------------------------------- cooperative determinism intact
def test_cooperative_run_equals_manual_ticks():
    """The facade's run() and hand-driven ticks are the same loop — the
    scheduler split must not change cooperative semantics."""
    rt_a = mk_runtime(total_steps=2)
    hist_a = rt_a.run(max_ticks=3000)

    rt_b = mk_runtime(total_steps=2)
    sched = rt_b.scheduler
    assert isinstance(sched, CooperativeScheduler)
    while rt_b.model_version < 2 and rt_b._tick < 3000:
        rt_b.tick()
    hist_b = rt_b.history

    assert len(hist_a) == len(hist_b) == 2
    for a, b in zip(hist_a, hist_b):
        assert a.step == b.step
        assert a.mean_reward == b.mean_reward
        assert a.loss == b.loss
        assert a.mean_is_ratio == b.mean_is_ratio
        assert a.staleness_hist == b.staleness_hist


def test_cooperative_history_is_seed_deterministic():
    """Fixed seed => bit-for-bit identical StepRecord history (rewards,
    losses, staleness hists) across fresh runtimes — the reproducibility
    contract the convergence suites rely on."""
    hists = []
    for _ in range(2):
        rt = mk_runtime(total_steps=2, temperature=1.0)
        hists.append(rt.run(max_ticks=3000))
    (ha, hb) = hists
    assert [r.loss for r in ha] == [r.loss for r in hb]
    assert [r.mean_reward for r in ha] == [r.mean_reward for r in hb]
    assert [r.staleness_hist for r in ha] == [r.staleness_hist for r in hb]
    assert [r.mean_is_ratio for r in ha] == [r.mean_is_ratio for r in hb]


def test_tick_refused_on_threaded_scheduler():
    rt = mk_runtime(scheduler="threaded")
    with pytest.raises(RuntimeError):
        rt.tick()
    rt.scheduler.shutdown()


# ------------------------------------------------- streaming pipeline
def test_threaded_streaming_trains_with_staleness_bound(lock_witnessed):
    """Streaming smoke: event-driven admission (route_instance off
    COMPLETED/ABORTED), partial-batch consumption, and the event-gated
    scheduler together still honor eta on every consumed batch."""
    rt = mk_runtime(scheduler="threaded", total_steps=2, streaming=True,
                    stream_min_fill=1)
    rt.scheduler.wall_timeout_s = 240.0
    history = rt.run()
    assert rt.model_version == 2
    for rec in history:
        assert np.isfinite(rec.loss)
        assert all(0 <= s <= rt.rcfg.eta for s in rec.staleness_hist)
    assert rt.manager.max_consumed_staleness() <= rt.rcfg.eta
    rt.manager.check_invariants()
    # the incremental fast path actually ran (not just background cycles)
    assert rt.coordinator.stats.stream_cycles > 0


@pytest.mark.slow
def test_threaded_streaming_stress_elastic_fleet(lock_witnessed):
    """Streaming stress: partial-batch consumption + incremental admission
    under real thread interleavings, with a replica failure and an elastic
    scale-up mid-run. The staleness bound and protocol invariants must
    survive every transition — under the lock witness."""
    rt = mk_runtime(
        scheduler="threaded", total_steps=3, n_instances=2, eta=2,
        batch_size=2, streaming=True, stream_min_fill=1,
        stream_rebalance_interval_s=0.01,
    )
    rt.scheduler.wall_timeout_s = 280.0
    runner = threading.Thread(target=rt.run, daemon=True)
    runner.start()
    deadline = time.perf_counter() + 120
    while time.perf_counter() < deadline:
        if rt.instances[1].decode_steps > 0 and rt.model_version >= 1:
            break
        time.sleep(0.05)
    assert rt.instances[1].decode_steps > 0, "instance 1 never decoded"

    rt.fail_instance(1)
    rt.manager.check_invariants()  # replica loss under streaming admission
    rt.add_instance(9)
    rt.manager.check_invariants()  # elastic scale-up

    runner.join(timeout=280)
    assert not runner.is_alive(), "threaded streaming run did not finish"
    assert rt.model_version == 3
    rt.manager.check_invariants()
    assert rt.manager.max_consumed_staleness() <= rt.rcfg.eta
    for hist in rt.manager.consumed_staleness:
        assert all(0 <= s <= rt.rcfg.eta for s in hist)
    assert 9 in rt.instances
    # lifecycle conservation: everything consumed was first rewarded
    counts = rt.lifecycle.counts
    from repro.core.lifecycle import LifecycleEventKind as K
    assert counts[K.CONSUMED] <= counts[K.REWARDED]
    assert counts[K.COMPLETED] >= counts[K.REWARDED] - counts[K.ABORTED]


def test_tick_streaming_is_deterministic():
    """Streaming under the cooperative scheduler stays single-threaded:
    incremental admission runs inside the deterministic event dispatch, so
    fixed seed still means bit-for-bit identical histories."""
    hists = []
    for _ in range(2):
        rt = mk_runtime(total_steps=2, max_slots=2, streaming=True,
                        stream_min_fill=1)
        h = rt.run(max_ticks=3000)
        assert rt.model_version == 2
        assert rt.manager.max_consumed_staleness() <= rt.rcfg.eta
        assert rt.coordinator.stats.stream_cycles > 0
        hists.append(
            [(r.loss, r.mean_reward, tuple(r.staleness_hist)) for r in h]
        )
    assert hists[0] == hists[1]
