"""Training substrate tests: optimizer, RL train step, checkpoint round-trip
(incl. protocol state), gradient compression with error feedback."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.staleness import StalenessManager
from repro.models import model as M
from repro.training import checkpoint as ckpt
from repro.training.compression import (
    ErrorFeedback,
    compressed_bytes,
    dequantize_int8,
    quantize_int8,
)
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.training.train_step import make_lm_train_step, make_rl_train_step

CFG = get_arch("qwen2-1.5b").reduced()
KEY = jax.random.PRNGKey(0)


def _rl_batch(b=4, t=24):
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1), (b, t), 3, 17)
    mask = jnp.zeros((b, t)).at[:, 8:].set(1.0)
    return {
        "tokens": tokens,
        "behavior_logprobs": jnp.full((b, t), -2.0) * mask,
        "advantages": jnp.asarray([1.0, -1.0, 0.5, -0.5]),
        "mask": mask,
    }


def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, grad_clip=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_rl_train_step_runs_and_improves_objective():
    params = M.init_params(CFG, KEY)
    opt = init_opt_state(params)
    step = jax.jit(make_rl_train_step(CFG, AdamWConfig(lr=3e-3)))
    batch = _rl_batch()
    losses = []
    for _ in range(5):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["pg_loss"]))
        assert np.isfinite(losses[-1])
        assert float(metrics["grad_norm"]) > 0
    assert losses[-1] < losses[0]  # same batch -> objective must improve


def test_rl_train_step_remat_matches_no_remat():
    params = M.init_params(CFG, KEY)
    opt = init_opt_state(params)
    batch = _rl_batch()
    s1 = make_rl_train_step(CFG, AdamWConfig(lr=1e-3), remat=False)
    s2 = make_rl_train_step(CFG, AdamWConfig(lr=1e-3), remat=True)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    np.testing.assert_allclose(
        float(m1["loss"]), float(m2["loss"]), rtol=1e-5
    )
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_rl_train_step_accum_matches_full_batch():
    """Gradient accumulation (the HBM-fit lever) must reproduce the
    full-batch update up to float tolerance."""
    params = M.init_params(CFG, KEY)
    opt = init_opt_state(params)
    batch = _rl_batch(b=4, t=24)
    s1 = make_rl_train_step(CFG, AdamWConfig(lr=1e-3), accum_steps=1)
    s2 = make_rl_train_step(CFG, AdamWConfig(lr=1e-3), accum_steps=2)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_lm_train_step_loss_decreases():
    params = M.init_params(CFG, KEY)
    opt = init_opt_state(params)
    step = jax.jit(make_lm_train_step(CFG, AdamWConfig(lr=3e-3)))
    batch = {"tokens": jax.random.randint(KEY, (4, 32), 3, 17)}
    first = last = None
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        first = first if first is not None else float(m["nll"])
        last = float(m["nll"])
    assert last < first


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_with_protocol(tmp_path):
    params = M.init_params(CFG, KEY)
    opt = init_opt_state(params)
    mgr = StalenessManager(batch_size=2, eta=1)
    mgr.reserve(1, 0)
    mgr.reserve(2, 0)
    mgr.occupy(1)

    path = ckpt.save_checkpoint(
        str(tmp_path), 7, params, opt,
        extra_meta={"model_version": 7},
        protocol_state=ckpt.dump_protocol_state(mgr),
    )
    assert os.path.exists(os.path.join(path, "meta.json"))
    assert ckpt.latest_step(str(tmp_path)) == 7

    p2, o2, meta = ckpt.restore_checkpoint(str(tmp_path), params, opt)
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), b)
    assert meta["extra"]["model_version"] == 7

    mgr2 = ckpt.load_protocol_state(meta["protocol"])
    assert mgr2.train_version == mgr.train_version
    assert mgr2.entry_info(1) == mgr.entry_info(1)
    assert mgr2.entry_info(2) == mgr.entry_info(2)
    mgr2.check_invariants()


def test_checkpoint_atomic_overwrite(tmp_path):
    params = {"w": jnp.ones((4,))}
    opt = init_opt_state(params)
    ckpt.save_checkpoint(str(tmp_path), 1, params, opt)
    params2 = {"w": jnp.full((4,), 2.0)}
    ckpt.save_checkpoint(str(tmp_path), 1, params2, opt)  # overwrite same step
    p, _, _ = ckpt.restore_checkpoint(str(tmp_path), params, opt, step=1)
    np.testing.assert_array_equal(p["w"], np.full((4,), 2.0))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    params = {"w": jnp.ones((4,))}
    opt = init_opt_state(params)
    ckpt.save_checkpoint(str(tmp_path), 0, params, opt)
    bad = {"w": jnp.ones((5,))}
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore_checkpoint(str(tmp_path), bad, init_opt_state(bad))


# ---------------------------------------------------------------- compression
def test_int8_quantization_bounded_error():
    x = jax.random.normal(KEY, (1024,))
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) / 2 + 1e-6


def test_error_feedback_converges_sum():
    """With error feedback, the SUM of compressed grads tracks the true sum
    (bias does not accumulate)."""
    g = jax.random.normal(KEY, (512,)) * 0.1
    ef = ErrorFeedback({"g": g})
    total_true = np.zeros(512)
    total_comp = np.zeros(512)
    res_at_100 = None
    for i in range(200):
        gi = {"g": g * (1 + 0.1 * np.sin(i))}
        out = ef.compress_grads(gi, scheme="topk", topk_rate=0.05)
        total_true += np.asarray(gi["g"])
        total_comp += np.asarray(out["g"])
        if i == 99:
            res_at_100 = float(np.linalg.norm(np.asarray(ef.residual["g"])))
    # residual is bounded (plateaus) -> cumulative error decays as 1/n
    rel = np.linalg.norm(total_comp - total_true) / np.linalg.norm(total_true)
    assert rel < 0.05
    res_final = float(np.linalg.norm(np.asarray(ef.residual["g"])))
    assert res_final < 1.1 * res_at_100  # no unbounded error accumulation


def test_compressed_bytes_accounting():
    g = {"a": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
    assert compressed_bytes(g, scheme="int8") == 1024 + 8
    assert compressed_bytes(g, scheme="topk", topk_rate=0.01) == (10 + 1) * 8
